//! Figure 5: inference-latency comparison of SparOA against all baselines
//! on the five models and both devices.  Paper headline numbers to match
//! in *shape*: up to 50.7x over CPU-Only (MobileNetV3 on AGX), 1.22-1.31x
//! over the SOTA compiler/co-execution baselines, 1.24-11.43x on Nano.

use sparoa::baselines::{Baseline, ALL};
use sparoa::bench_support::{load_env, Table, DEVICES, MODELS};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let episodes = std::env::var("SPAROA_EPISODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    let mut speedup_sota: Vec<f64> = Vec::new();
    let mut speedup_cpu: Vec<f64> = Vec::new();
    for device in DEVICES {
        let dev = reg.get(device).unwrap();
        let mut t = Table::new(
            &format!("Fig.5 — latency on {device} (us, batch 1)"),
            &["baseline", "resnet18", "mbv3-s", "mbv2", "vit_b16",
              "swin_t"],
        );
        // latency[baseline][model]
        let mut lat = vec![vec![0.0f64; MODELS.len()]; ALL.len()];
        for (mi, model) in MODELS.iter().enumerate() {
            let g = zoo.get(model).unwrap();
            for (bi, b) in ALL.iter().enumerate() {
                let ep = if *b == Baseline::Sparoa { episodes } else { 0 };
                let (_, rep) = b.run(g, dev, None, 1, ep);
                lat[bi][mi] = rep.makespan_us;
            }
        }
        let sparoa_idx = ALL
            .iter()
            .position(|b| *b == Baseline::Sparoa)
            .unwrap();
        for (bi, b) in ALL.iter().enumerate() {
            let mut row = vec![b.name().to_string()];
            for mi in 0..MODELS.len() {
                row.push(format!("{:.0}", lat[bi][mi]));
            }
            t.row(row);
        }
        t.print();

        let mut s = Table::new(
            &format!("Fig.5 — speedup of SparOA vs baseline ({device})"),
            &["baseline", "resnet18", "mbv3-s", "mbv2", "vit_b16",
              "swin_t"],
        );
        for (bi, b) in ALL.iter().enumerate() {
            if bi == sparoa_idx {
                continue;
            }
            let mut row = vec![b.name().to_string()];
            for mi in 0..MODELS.len() {
                let x = lat[bi][mi] / lat[sparoa_idx][mi];
                row.push(format!("{x:.2}x"));
                if matches!(b, Baseline::TensorRt | Baseline::Tvm
                            | Baseline::Ios | Baseline::Pos
                            | Baseline::CoDl) {
                    speedup_sota.push(x);
                }
                if *b == Baseline::CpuOnly && device == "agx_orin" {
                    speedup_cpu.push(x);
                }
            }
            s.row(row);
        }
        s.print();
    }
    let mean_sota =
        speedup_sota.iter().sum::<f64>() / speedup_sota.len() as f64;
    let max_cpu = speedup_cpu.iter().cloned().fold(0.0, f64::max);
    println!(
        "\nHeadline vs paper: mean speedup over SOTA \
         compilers/co-execution = {mean_sota:.2}x (paper 1.22-1.31x); \
         max over CPU-Only on AGX = {max_cpu:.1}x (paper 50.7x)."
    );
}
