//! Multi-tenant serving invariants — always-on (synthetic models +
//! checked-in device profiles; no `make artifacts` gating):
//!
//! * conservation: every offered request is served exactly once or
//!   accounted as shed; nothing is lost, nothing is double-served;
//! * bounded queues: admission control sheds under overload instead of
//!   queueing without limit;
//! * priority: higher SLO classes on the same model never do worse than
//!   lower ones under overload, and never starve;
//! * the acceptance comparison: under overload the cross-model cluster
//!   scheduler beats the static CPU/GPU split on aggregate attainment.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::graph::ModelGraph;
use sparoa::serve::{
    demo, merge_arrivals, run_cluster, ArrivalPattern, ClusterOptions,
    ClusterPolicy, ModelRegistry, ShedPolicy, SloClass, Tenant,
};

fn registry_of(models: &[(&str, usize, f64, f64)]) -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in models {
        let session = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, *blocks, *scale, *sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(session).unwrap();
    }
    reg
}

#[test]
fn conservation_under_random_mixes() {
    // Across random tenant mixes, rates, class caps and shed policies:
    // offered == served + shed, and per-class/per-model accounting agree.
    let reg = registry_of(&[
        ("m_big", 6, 3.0, 0.2),
        ("m_small", 4, 0.4, 0.7),
    ]);
    let sheds = [
        ShedPolicy::RejectNew,
        ShedPolicy::ShedOldest,
        ShedPolicy::ShedLowestClass,
    ];
    prop::check(
        "serve-conservation",
        12,
        1701,
        |rng| {
            let rate0 = rng.range(20.0, 800.0);
            let rate1 = rng.range(20.0, 800.0);
            let cap0 = 4 + rng.below(40);
            let cap1 = 4 + rng.below(60);
            let shed = sheds[rng.below(3)];
            let policy = if rng.below(2) == 0 {
                ClusterPolicy::SparsityAware
            } else {
                ClusterPolicy::StaticSplit
            };
            let seed = rng.next_u64() % 10_000;
            (rate0, rate1, cap0, cap1, shed, policy, seed)
        },
        |&(rate0, rate1, cap0, cap1, shed, policy, seed)| {
            let classes = vec![
                SloClass::new("hi", 15_000.0, cap0, 4.0),
                SloClass::new("lo", 80_000.0, cap1, 1.0),
            ];
            let tenants = vec![
                Tenant {
                    name: "a".into(),
                    model: "m_big".into(),
                    class: 0,
                    pattern: ArrivalPattern::Poisson {
                        rate_per_s: rate0,
                        n: 120,
                    },
                },
                Tenant {
                    name: "b".into(),
                    model: "m_small".into(),
                    class: 1,
                    pattern: ArrivalPattern::Mmpp {
                        rate_lo_per_s: rate1 * 0.2,
                        rate_hi_per_s: rate1 * 2.0,
                        mean_dwell_s: 0.05,
                        n: 120,
                    },
                },
            ];
            let arrivals = merge_arrivals(&tenants, seed);
            let snap = run_cluster(&reg, &classes, &tenants, &arrivals,
                &ClusterOptions { policy, shed, trace: None })
                .map_err(|e| e.to_string())?;
            let offered = snap.total_offered();
            if offered != arrivals.len() as u64 {
                return Err(format!(
                    "offered {offered} != arrivals {}", arrivals.len()));
            }
            if snap.total_served() + snap.total_shed() != offered {
                return Err(format!(
                    "lost requests: served {} + shed {} != offered \
                     {offered}",
                    snap.total_served(), snap.total_shed()));
            }
            for g in snap.per_class.iter().chain(&snap.per_model) {
                if g.served + g.shed() != g.offered {
                    return Err(format!(
                        "group `{}` unbalanced: {} + {} != {}",
                        g.label, g.served, g.shed(), g.offered));
                }
                if g.hist.count() != g.served {
                    return Err(format!(
                        "group `{}` served {} but recorded {} latencies",
                        g.label, g.served, g.hist.count()));
                }
                if g.met > g.served {
                    return Err(format!(
                        "group `{}` met {} > served {}",
                        g.label, g.met, g.served));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn overload_sheds_instead_of_queueing_unboundedly() {
    let reg = registry_of(&[("m_only", 6, 4.0, 0.3)]);
    // Tiny queue budgets + heavy overload: shedding must kick in, and
    // served + shed still balances.
    let classes = vec![
        SloClass::new("hi", 10_000.0, 8, 4.0),
        SloClass::new("lo", 50_000.0, 8, 1.0),
    ];
    let tenants = vec![
        Tenant {
            name: "hi".into(),
            model: "m_only".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson { rate_per_s: 900.0, n: 600 },
        },
        Tenant {
            name: "lo".into(),
            model: "m_only".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson { rate_per_s: 900.0, n: 600 },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 5);
    for shed in [
        ShedPolicy::RejectNew,
        ShedPolicy::ShedOldest,
        ShedPolicy::ShedLowestClass,
    ] {
        let snap = run_cluster(&reg, &classes, &tenants, &arrivals,
            &ClusterOptions {
                policy: ClusterPolicy::SparsityAware,
                shed,
                trace: None,
            })
            .unwrap();
        assert!(snap.total_shed() > 0,
                "{}: overload must shed", shed.name());
        assert_eq!(snap.total_served() + snap.total_shed(),
                   snap.total_offered());
        // Dispatched batches never exceed the Alg. 2 caps.
        let e = reg.get(0);
        assert!(snap.mean_batch()
                <= e.gpu_batch_cap.max(e.cpu_batch_cap) as f64 + 1e-9);
    }
}

#[test]
fn higher_class_never_does_worse_on_shared_model() {
    // Two tenants, same model, same arrival process — only the SLO class
    // differs.  Under overload the high-priority class must come out at
    // least as well (attainment) and must actually be served.
    let reg = registry_of(&[("m_shared", 6, 3.0, 0.3)]);
    let classes = vec![
        SloClass::new("hi", 25_000.0, 64, 4.0),
        SloClass::new("lo", 25_000.0, 64, 1.0),
    ];
    let mk = |class: usize| Tenant {
        name: format!("c{class}"),
        model: "m_shared".into(),
        class,
        pattern: ArrivalPattern::Poisson { rate_per_s: 700.0, n: 500 },
    };
    let tenants = vec![mk(0), mk(1)];
    let arrivals = merge_arrivals(&tenants, 17);
    for shed in [ShedPolicy::RejectNew, ShedPolicy::ShedLowestClass] {
        let snap = run_cluster(&reg, &classes, &tenants, &arrivals,
            &ClusterOptions {
                policy: ClusterPolicy::SparsityAware,
                shed,
                trace: None,
            })
            .unwrap();
        let hi = &snap.per_class[0];
        let lo = &snap.per_class[1];
        assert!(hi.met > 0, "{}: high class starved", shed.name());
        assert!(
            hi.attainment() >= lo.attainment() - 1e-9,
            "{}: high class attainment {:.3} < low {:.3}",
            shed.name(), hi.attainment(), lo.attainment()
        );
    }
}

#[test]
fn cluster_beats_static_split_under_overload() {
    // The tentpole acceptance criterion: >= 3 models, >= 2 SLO classes,
    // >= 3 arrival patterns; under overload the sparsity-aware
    // cross-model scheduler achieves higher aggregate SLO attainment
    // than per-model single-queue batching on a static CPU/GPU split.
    let artifacts = sparoa::artifacts_dir();
    let reg = demo::registry(&artifacts, "agx_orin").unwrap();
    let classes = demo::classes();
    let tenants = demo::tenants(&reg, 3.0, 300, 29, None).unwrap();
    assert!(reg.len() >= 3);
    assert!(classes.len() >= 2);
    let kinds: std::collections::BTreeSet<&str> =
        tenants.iter().map(|t| t.pattern.kind()).collect();
    assert!(kinds.len() >= 3, "patterns {kinds:?}");
    let arrivals = merge_arrivals(&tenants, 29);

    let dynamic = run_cluster(&reg, &classes, &tenants, &arrivals,
        &ClusterOptions {
            policy: ClusterPolicy::SparsityAware,
            ..Default::default()
        })
        .unwrap();
    let static_split = run_cluster(&reg, &classes, &tenants, &arrivals,
        &ClusterOptions {
            policy: ClusterPolicy::StaticSplit,
            ..Default::default()
        })
        .unwrap();
    assert!(
        dynamic.aggregate_attainment()
            > static_split.aggregate_attainment(),
        "cluster {:.3} vs static split {:.3}",
        dynamic.aggregate_attainment(),
        static_split.aggregate_attainment()
    );
    // Both processors are actually used by the dynamic tier.
    assert!(dynamic.gpu_busy_us > 0.0);
    assert!(dynamic.cpu_busy_us > 0.0);
    // And the low-load sanity check: the cluster meets nearly all SLOs.
    let calm_tenants = demo::tenants(&reg, 0.2, 150, 31, None).unwrap();
    let calm_arrivals = merge_arrivals(&calm_tenants, 31);
    let calm = run_cluster(&reg, &classes, &calm_tenants, &calm_arrivals,
        &ClusterOptions::default())
        .unwrap();
    assert!(calm.aggregate_attainment() > 0.85,
            "calm attainment {:.3}", calm.aggregate_attainment());
}

#[test]
fn trace_replay_drives_the_cluster() {
    // A JSON trace round-trips into a tenant and its requests are all
    // accounted.
    let reg = registry_of(&[
        ("m_a", 4, 1.0, 0.4),
        ("m_b", 4, 0.5, 0.6),
        ("m_c", 5, 2.0, 0.2),
    ]);
    let src: Vec<f64> = (0..200).map(|i| i as f64 * 2_500.0).collect();
    let text = sparoa::serve::trace_to_json(&src);
    let pattern = sparoa::serve::trace_from_json(&text).unwrap();
    let tenants =
        demo::tenants(&reg, 1.0, 100, 3, Some(pattern)).unwrap();
    let replay = tenants.iter().find(|t| t.name == "replay-trace").unwrap();
    assert_eq!(replay.pattern.len(), 200);
    let arrivals = merge_arrivals(&tenants, 3);
    let classes = demo::classes();
    let snap = run_cluster(&reg, &classes, &tenants, &arrivals,
                           &ClusterOptions::default())
        .unwrap();
    assert_eq!(snap.total_offered() as usize, arrivals.len());
    assert_eq!(snap.total_served() + snap.total_shed(),
               snap.total_offered());
}
