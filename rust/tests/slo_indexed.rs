//! Indexed-dispatch pin tests — always-on.
//!
//! The PR-3 `simulate_reference` playbook applied to the serving
//! queues: the sorted-on-insert [`AdmissionQueues`] must behave
//! identically to the original flat-vec clone+sort implementation
//! (kept verbatim as [`ReferenceQueues`]) across randomized
//! offer/take/shed/expire interleavings under all three shed policies:
//! same admitted counts, same queue contents in the same dispatch
//! order, same take-batch drains, same shed victims with the same
//! at-admission flags.
//!
//! Two reference behaviors are permutation artifacts of its in-place
//! sorts, not specified semantics, and the indexed path canonicalizes
//! them to admission order (see the `serve::slo` module docs).  The
//! pin therefore (a) compares shed logs as multisets plus the exact
//! relative order of admission-time sheds — within-sweep expiry
//! emission order is the artifact, and every downstream consumer is an
//! order-insensitive counter — and (b) exercises strict-FIFO takes
//! only in the unique-arrival-time mode, where they are fully
//! determined (on exact f64 arrival ties the reference's FIFO order
//! depends on its sort history).  Class-ordered takes — the path every
//! sparsity-aware board uses — are pinned exactly in both modes,
//! including exact-tie scenarios.
//!
//! The whole pin (both modes, all policies) was additionally validated
//! against a Python mirror of the two implementations over 6000
//! randomized cases before porting.
//!
//! Plus the fleet re-check: `run_fleet`'s event-heap clock conserves
//! every request across routers, shed policies and the autoscaler.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::graph::ModelGraph;
use sparoa::serve::slo::ReferenceQueues;
use sparoa::serve::{
    merge_arrivals, run_fleet, spread_placement, AdmissionQueues,
    ArrivalPattern, AutoscalePolicy, FleetOptions, ModelRegistry,
    QueuedReq, RouterPolicy, ShedPolicy, ShedReq, SloClass, Tenant,
};
use sparoa::util::rng::Rng;

/// One random queue operation.
#[derive(Debug, Clone, Copy)]
enum QueueOp {
    /// Offer at `clock + jitter` (jitter may be negative: out-of-order
    /// admissions are part of the contract; in tie mode it is
    /// quantized so exact arrival collisions actually occur).
    Offer { model: usize, class: usize, jitter: f64 },
    /// Drain up to `max` requests of `model`.
    Take { model: usize, max: usize, class_order: bool },
    /// Advance the clock and shed everything expired.
    Expire { advance: f64 },
}

#[derive(Debug, Clone)]
struct Scenario {
    policy: ShedPolicy,
    n_models: usize,
    /// (deadline_us, queue_cap, weight) per class.
    classes: Vec<(f64, usize, f64)>,
    ops: Vec<QueueOp>,
    /// Tie mode: quantized arrivals (exact collisions), class-ordered
    /// takes only.  Unique mode: continuous arrivals, FIFO takes too.
    ties: bool,
}

fn gen_scenario(rng: &mut Rng, ties: bool) -> Scenario {
    let policies = [
        ShedPolicy::RejectNew,
        ShedPolicy::ShedOldest,
        ShedPolicy::ShedLowestClass,
    ];
    let policy = policies[rng.below(3)];
    let n_models = 1 + rng.below(3);
    let n_classes = 2 + rng.below(2);
    let classes: Vec<(f64, usize, f64)> = (0..n_classes)
        .map(|i| {
            (
                rng.range(5.0, 60.0),
                1 + rng.below(8),
                (n_classes - i) as f64,
            )
        })
        .collect();
    let n_ops = 40 + rng.below(80);
    let ops: Vec<QueueOp> = (0..n_ops)
        .map(|_| match rng.below(10) {
            0..=5 => QueueOp::Offer {
                model: rng.below(n_models),
                class: rng.below(n_classes),
                jitter: if ties {
                    rng.range(-6.0, 10.0).round() * 0.5
                } else {
                    rng.range(-6.0, 10.0)
                },
            },
            6..=7 => QueueOp::Take {
                model: rng.below(n_models),
                max: rng.below(6),
                class_order: ties || rng.below(2) == 0,
            },
            _ => QueueOp::Expire { advance: rng.range(0.0, 25.0) },
        })
        .collect();
    Scenario { policy, n_models, classes, ops, ties }
}

/// Shed-log comparison: identical multisets (same victims, flags) and
/// identical relative order of admission-time sheds (those are emitted
/// synchronously, one per offer, in both implementations).
fn compare_sheds(a: &[ShedReq], b: &[ShedReq]) -> Result<(), String> {
    let key = |s: &ShedReq| (s.req, s.at_admission, s.model, s.class);
    let mut ka: Vec<_> = a.iter().map(key).collect();
    let mut kb: Vec<_> = b.iter().map(key).collect();
    ka.sort();
    kb.sort();
    if ka != kb {
        return Err(format!(
            "shed multiset diverged:\n  indexed:   {a:?}\n  \
             reference: {b:?}"));
    }
    let adm_a: Vec<&ShedReq> =
        a.iter().filter(|s| s.at_admission).collect();
    let adm_b: Vec<&ShedReq> =
        b.iter().filter(|s| s.at_admission).collect();
    if adm_a != adm_b {
        return Err(format!(
            "admission-shed order diverged:\n  indexed:   {adm_a:?}\n  \
             reference: {adm_b:?}"));
    }
    Ok(())
}

/// Full-state comparison after every operation.
fn compare_states(
    a: &AdmissionQueues,
    b: &ReferenceQueues,
    n_models: usize,
) -> Result<(), String> {
    if a.admitted != b.admitted {
        return Err(format!(
            "admitted diverged: {} vs {}", a.admitted, b.admitted));
    }
    if a.total_queued() != b.total_queued() {
        return Err(format!(
            "total_queued diverged: {} vs {}",
            a.total_queued(), b.total_queued()));
    }
    compare_sheds(&a.shed, &b.shed)?;
    for m in 0..n_models {
        if a.queue_len(m) != b.queue_len(m) {
            return Err(format!(
                "queue_len({m}) diverged: {} vs {}",
                a.queue_len(m), b.queue_len(m)));
        }
        let sorted_ref = b.sorted_queue(m);
        let sorted_idx = a.sorted_queue_reference(m);
        if sorted_idx != sorted_ref {
            return Err(format!(
                "sorted queue {m} diverged:\n  indexed:   {sorted_idx:?}\
                 \n  reference: {sorted_ref:?}"));
        }
        let view: Vec<QueuedReq> = a.dispatch_view(m).copied().collect();
        if view != sorted_ref {
            return Err(format!(
                "dispatch_view({m}) is not the sorted order:\n  view: \
                 {view:?}\n  sorted: {sorted_ref:?}"));
        }
        let head = a.head_arrival_us(m);
        let min = sorted_ref
            .iter()
            .map(|r| r.arrival_us)
            .fold(f64::INFINITY, f64::min);
        if head.to_bits() != min.to_bits() {
            return Err(format!(
                "head_arrival_us({m}) diverged: {head} vs {min}"));
        }
    }
    Ok(())
}

fn run_pin(sc: &Scenario) -> Result<(), String> {
    let classes: Vec<SloClass> = sc
        .classes
        .iter()
        .enumerate()
        .map(|(i, &(d, cap, w))| SloClass::new(&format!("c{i}"), d, cap, w))
        .collect();
    let mut idx = AdmissionQueues::new(&classes, sc.policy, sc.n_models);
    let mut refq = ReferenceQueues::new(&classes, sc.policy, sc.n_models);
    // Unique mode starts the clock above the jitter range so the >= 0
    // clamp cannot manufacture arrival ties at t = 0.
    let mut clock = if sc.ties { 0.0f64 } else { 10.0f64 };
    let mut req = 0usize;
    for op in &sc.ops {
        match *op {
            QueueOp::Offer { model, class, jitter } => {
                let t = (clock + jitter).max(0.0);
                let tenant = req % 5;
                idx.offer(req, tenant, model, class, t);
                refq.offer(req, tenant, model, class, t);
                req += 1;
                clock += 0.5;
            }
            QueueOp::Take { model, max, class_order } => {
                let ta = idx.take_batch(model, max, class_order);
                let tb = refq.take_batch(model, max, class_order);
                if ta != tb {
                    return Err(format!(
                        "take_batch diverged:\n  indexed:   {ta:?}\n  \
                         reference: {tb:?}"));
                }
            }
            QueueOp::Expire { advance } => {
                clock += advance;
                idx.drop_expired(clock);
                refq.drop_expired(clock);
            }
        }
        compare_states(&idx, &refq, sc.n_models)?;
    }
    // Drain everything at the end: the final takes must agree too, and
    // both must come out empty.
    for m in 0..sc.n_models {
        let ta = idx.take_batch(m, usize::MAX, true);
        let tb = refq.take_batch(m, usize::MAX, true);
        if ta != tb {
            return Err(format!("final drain diverged on model {m}"));
        }
    }
    if idx.total_queued() != 0 || refq.total_queued() != 0 {
        return Err("drain left residue".into());
    }
    Ok(())
}

#[test]
fn indexed_queues_pin_to_reference_with_arrival_ties() {
    prop::check(
        "slo-indexed-pin-ties",
        40,
        0x51_0D15_u64,
        |rng| gen_scenario(rng, true),
        run_pin,
    );
}

#[test]
fn indexed_queues_pin_to_reference_with_unique_arrivals() {
    prop::check(
        "slo-indexed-pin-unique",
        40,
        0x51_0D16_u64,
        |rng| gen_scenario(rng, false),
        run_pin,
    );
}

/// heavy = 0, light = 1 synthetic registry for the fleet re-check.
fn registry2() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in
        [("eh_heavy", 5, 3.0, 0.2), ("eh_light", 4, 0.4, 0.7)]
    {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

#[test]
fn event_heap_fleet_loop_conserves_requests() {
    // The fleet clock now advances off a wake-up heap and skips idle
    // boards; conservation must hold exactly as before across every
    // router, shed policy and the autoscaler's tick path.
    let reg = registry2();
    let classes = vec![
        SloClass::new("hi", 25_000.0, 32, 4.0),
        SloClass::new("lo", 120_000.0, 64, 1.0),
    ];
    let tenants = vec![
        Tenant {
            name: "a".into(),
            model: "eh_heavy".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 400.0,
                n: 250,
            },
        },
        Tenant {
            name: "b".into(),
            model: "eh_light".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 700.0,
                n: 250,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 77);
    let runs = [
        (RouterPolicy::RoundRobin, ShedPolicy::RejectNew, false),
        (RouterPolicy::JoinShortestQueue, ShedPolicy::ShedOldest, false),
        (RouterPolicy::CostAware, ShedPolicy::ShedLowestClass, false),
        (RouterPolicy::CostAware, ShedPolicy::ShedLowestClass, true),
    ];
    for (router, shed, autoscale) in runs {
        let mut opts = FleetOptions {
            router,
            shed,
            placement: spread_placement(3, &[2, 2]),
            ..FleetOptions::new(3, 2)
        };
        if autoscale {
            opts.autoscale = Some(AutoscalePolicy {
                interval_us: 30_000.0,
                warmup_us: 10_000.0,
                ..Default::default()
            });
        }
        let snap =
            run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                .unwrap();
        assert_eq!(
            snap.aggregate.total_offered() as usize,
            arrivals.len(),
            "{}/{}: router lost or duplicated requests",
            router.name(), shed.name()
        );
        assert_eq!(
            snap.aggregate.total_served() + snap.aggregate.total_shed(),
            snap.aggregate.total_offered(),
            "{}/{}: conservation broken",
            router.name(), shed.name()
        );
        let per_board: u64 =
            snap.boards.iter().map(|b| b.total_offered()).sum();
        assert_eq!(per_board, snap.aggregate.total_offered(),
                   "per-board offered does not sum to aggregate");
        for (i, b) in snap.boards.iter().enumerate() {
            assert_eq!(
                b.total_served() + b.total_shed(),
                b.total_offered(),
                "board {i} unbalanced"
            );
        }
    }
}
