//! Profiler reconciliation invariants — always-on (synthetic models +
//! checked-in device profiles; no `make artifacts` gating):
//!
//! * conservation: every admitted request appears in the trace exactly
//!   once as served (`QueueWait`), or shed (`Shed` at admission /
//!   `Expire` in queue); nothing is lost or double-counted, and the
//!   trace totals pin the snapshot aggregates;
//! * capacity identity: per-phase sums (service + warm-up + idle)
//!   reproduce the board's lane-µs capacity to 1e-6 relative;
//! * power reconciliation: `Throttle` trace events equal the
//!   snapshot's `throttle_events` on every board and in aggregate;
//! * bounded buffers: the power busy-interval trace respects its cap
//!   and counts what it drops; the event ring counts drops too;
//! * exporters: folded stacks parse line-by-line and the Chrome trace
//!   is valid JSON with the `ph`/`ts`/`pid` schema Perfetto expects.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::device_profile;
use sparoa::device::Proc;
use sparoa::faults::{Fault, FaultPlan};
use sparoa::graph::ModelGraph;
use sparoa::obs::{TraceConfig, TraceEvent, TraceRecord};
use sparoa::power::{Governor, PowerConfig, PowerProfile};
use sparoa::serve::{
    merge_arrivals, run_cluster, run_fleet, ArrivalPattern,
    ClusterOptions, ClusterPolicy, FleetOptions, ModelRegistry,
    PerfSnapshot, PreemptionPolicy, RouterPolicy, ShedPolicy, SloClass,
    TailParams, TailPolicy, Tenant,
};

fn registry_of(models: &[(&str, usize, f64, f64)]) -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in models {
        let session = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, *blocks, *scale, *sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(session).unwrap();
    }
    reg
}

fn count(events: &[TraceRecord], pred: impl Fn(&TraceEvent) -> bool)
    -> u64
{
    events.iter().filter(|r| pred(&r.event)).count() as u64
}

/// Overloaded two-model / two-class mix under `RejectNew` (the one
/// shed policy where "admitted" is monotone: admitted requests are
/// never evicted, only served or expired — which is what makes the
/// Admit count verifiable).
fn overloaded_snapshot() -> PerfSnapshot {
    let reg = registry_of(&[
        ("m_big", 6, 3.0, 0.2),
        ("m_small", 4, 0.4, 0.7),
    ]);
    let classes = vec![
        SloClass::new("hi", 15_000.0, 8, 4.0),
        SloClass::new("lo", 80_000.0, 16, 1.0),
    ];
    let tenants = vec![
        Tenant {
            name: "a".into(),
            model: "m_big".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 600.0,
                n: 400,
            },
        },
        Tenant {
            name: "b".into(),
            model: "m_small".into(),
            class: 1,
            pattern: ArrivalPattern::Mmpp {
                rate_lo_per_s: 100.0,
                rate_hi_per_s: 900.0,
                mean_dwell_s: 0.05,
                n: 400,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 23);
    run_cluster(&reg, &classes, &tenants, &arrivals, &ClusterOptions {
        policy: ClusterPolicy::SparsityAware,
        shed: ShedPolicy::RejectNew,
        trace: Some(TraceConfig::default()),
    })
    .unwrap()
}

#[test]
fn every_admitted_request_is_accounted_exactly_once() {
    let snap = overloaded_snapshot();
    assert_eq!(snap.trace_dropped, 0, "default ring must not drop here");
    assert!(!snap.trace_events.is_empty());
    assert!(!snap.phases.is_empty());
    assert!(snap.total_shed() > 0, "overload must shed");

    let admits =
        count(&snap.trace_events, |e| matches!(e, TraceEvent::Admit));
    let waits = count(&snap.trace_events,
                      |e| matches!(e, TraceEvent::QueueWait { .. }));
    let sheds =
        count(&snap.trace_events, |e| matches!(e, TraceEvent::Shed));
    let expires =
        count(&snap.trace_events, |e| matches!(e, TraceEvent::Expire));

    let row_served: u64 = snap.phases.rows.iter().map(|r| r.served).sum();
    let row_shed: u64 = snap.phases.rows.iter().map(|r| r.shed).sum();
    let row_expired: u64 =
        snap.phases.rows.iter().map(|r| r.expired).sum();

    // Trace counters == phase accumulators == snapshot aggregates.
    assert_eq!(waits, snap.total_served());
    assert_eq!(row_served, snap.total_served());
    assert_eq!(sheds, row_shed);
    assert_eq!(expires, row_expired);
    assert_eq!(row_shed + row_expired, snap.total_shed());
    // Exactly-once accounting: an admitted request is served or
    // expires in queue; a rejected one sheds at admission.
    assert_eq!(admits, waits + expires, "admitted = served + expired");
    assert_eq!(admits + sheds, snap.total_offered());
}

fn assert_capacity_identity(snap: &PerfSnapshot, what: &str) {
    let p = &snap.phases;
    assert!(p.capacity_us > 0.0, "{what}: no capacity sealed");
    let accounted = p.service_us() + p.warmup_us + p.idle_us;
    let rel = (accounted - p.capacity_us).abs() / p.capacity_us;
    assert!(
        rel < 1e-6,
        "{what}: service {} + warmup {} + idle {} != capacity {} \
         (relative error {rel})",
        p.service_us(), p.warmup_us, p.idle_us, p.capacity_us
    );
    // Per-row split stays self-consistent: dma + compute == service.
    for r in &p.rows {
        assert!(r.dma_us >= 0.0 && r.compute_us >= 0.0);
        assert!(r.queue_wait_us >= 0.0);
    }
}

#[test]
fn phase_sums_reproduce_the_capacity_horizon() {
    let snap = overloaded_snapshot();
    assert_capacity_identity(&snap, "run_cluster");
    // Lane busy time (batches + warm-ups) is exactly what the service
    // and warm-up phases attribute.
    let busy = snap.cpu_busy_us + snap.gpu_busy_us;
    let attributed = snap.phases.service_us() + snap.phases.warmup_us;
    let rel = (attributed - busy).abs() / busy.max(1e-12);
    assert!(rel < 1e-6,
            "attributed {attributed} vs busy {busy} (rel {rel})");
}

#[test]
fn disabled_tracer_leaves_no_trace() {
    let reg = registry_of(&[("m_only", 4, 1.0, 0.4)]);
    let classes = vec![SloClass::new("c", 50_000.0, 64, 1.0)];
    let tenants = vec![Tenant {
        name: "t".into(),
        model: "m_only".into(),
        class: 0,
        pattern: ArrivalPattern::Poisson { rate_per_s: 200.0, n: 150 },
    }];
    let arrivals = merge_arrivals(&tenants, 7);
    let snap = run_cluster(&reg, &classes, &tenants, &arrivals,
        &ClusterOptions {
            policy: ClusterPolicy::SparsityAware,
            shed: ShedPolicy::RejectNew,
            trace: None,
        })
        .unwrap();
    assert!(snap.trace_events.is_empty());
    assert_eq!(snap.trace_dropped, 0);
    assert!(snap.phases.is_empty());
}

/// The serve_energy fixture, trimmed: one heavy + one light model, a
/// cap that fits the GPU's mid rung but not its top rung, so
/// race-to-idle's picks get clamped/deferred throughout the run.
fn capped_fleet() -> sparoa::serve::FleetSnapshot {
    let reg = registry_of(&[
        ("heavy", 8, 6.0, 0.1),
        ("light", 4, 0.3, 0.75),
    ]);
    let heavy = reg.get(0);
    let cap_b = heavy.gpu_batch_cap.max(1);
    let heavy_rate =
        cap_b as f64 / heavy.latency_us(Proc::Gpu, cap_b).unwrap() * 1e6;
    let heavy_batch_lat = heavy.latency_us(Proc::Gpu, cap_b).unwrap();
    let classes = vec![
        SloClass::new("standard", 3.5 * heavy_batch_lat, 256, 2.0),
        SloClass::new("best-effort", 15.0 * heavy_batch_lat, 512, 1.0),
    ];
    let tenants = vec![
        Tenant {
            name: "heavy-std".into(),
            model: "heavy".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 0.8 * heavy_rate,
                n: 220,
            },
        },
        Tenant {
            name: "light-be".into(),
            model: "light".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 0.8 * heavy_rate,
                n: 110,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 17);
    let profile =
        PowerProfile::from_device(&device_profile("agx_orin")).unwrap();
    let cap = profile.soc_static_w
        + profile.cpu.idle_w
        + profile.gpu.states[1].busy_power_w()
        + 0.01;
    let mut pc = PowerConfig::new(profile, Governor::RaceToIdle);
    pc.cap_w = Some(cap);
    pc.trace = true;
    pc.trace_cap = 4; // force busy-interval trace overflow too
    let mut opts = FleetOptions::new(2, 2);
    opts.power = Some(pc);
    opts.trace = Some(TraceConfig::default());
    run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap()
}

#[test]
fn throttle_trace_reconciles_with_power_accounting() {
    let snap = capped_fleet();
    assert!(snap.total_throttles() >= 1,
            "binding cap must surface throttles");
    let mut traced = 0u64;
    for (b, board) in snap.boards.iter().enumerate() {
        let n = count(&board.trace_events,
                      |e| matches!(e, TraceEvent::Throttle));
        assert_eq!(n, board.throttle_events,
                   "board {b}: trace vs snapshot throttles");
        assert_eq!(n, board.phases.throttles,
                   "board {b}: trace vs phase throttles");
        assert_capacity_identity(board, "fleet board");
        traced += n;
    }
    assert_eq!(traced, snap.total_throttles());
    assert_eq!(snap.aggregate.phases.throttles, snap.total_throttles());
}

#[test]
fn power_trace_is_bounded_and_drops_are_counted() {
    let snap = capped_fleet();
    let mut dropped = 0u64;
    for (b, board) in snap.boards.iter().enumerate() {
        assert!(board.power_trace.len() <= 4,
                "board {b}: trace_cap=4 but {} intervals kept",
                board.power_trace.len());
        dropped += board.power_trace_dropped;
    }
    assert!(dropped > 0,
            "220+ dispatches against trace_cap=4 must drop intervals");
    assert_eq!(snap.aggregate.power_trace_dropped, dropped);
}

#[test]
fn exporters_emit_wellformed_output() {
    let snap = overloaded_snapshot();

    // Folded stacks: `frames... count`, count a non-negative integer,
    // frames ';'-separated with the board label first.
    let folded = snap.folded_trace();
    assert!(!folded.trim().is_empty());
    for line in folded.lines() {
        let (stack, n) = line.rsplit_once(' ')
            .unwrap_or_else(|| panic!("unsplittable line `{line}`"));
        n.parse::<u64>()
            .unwrap_or_else(|_| panic!("bad count in `{line}`"));
        assert!(stack.starts_with(&snap.policy),
                "stack `{stack}` missing board frame");
    }
    assert!(folded.lines().any(|l| l.contains(";idle ")),
            "idle frame missing");

    // Chrome trace: valid JSON, events carry ph/ts/pid.
    let chrome = snap.chrome_trace();
    let v = sparoa::util::json::parse(&chrome).expect("invalid JSON");
    let events = v.get("traceEvents").as_arr().expect("no traceEvents");
    assert!(!events.is_empty());
    for e in events {
        assert!(e.get("ph").as_str().is_some(), "event without ph");
        assert!(e.get("ts").as_f64().is_some(), "event without ts");
        assert!(e.get("pid").as_f64().is_some(), "event without pid");
        assert!(e.get("name").as_str().is_some(), "event without name");
    }
}

/// Preemption-friendly traced fleet: heavy best-effort floods boards
/// 0/1 (the only heavy hosts) at 1.8x their capacity while a light
/// interactive stream round-robins across all three boards.  The
/// interactive deadline (10x the light batch-1 latency) burns behind
/// any in-flight heavy batch, and its weight outranks a full
/// best-effort batch, so DeadlineBurn fires; board 2 hosts only the
/// light model and idles, so BurnPlusSteal reliably re-places the
/// light queues stranded on boards 0/1.
fn preempting_fleet(preempt: PreemptionPolicy)
    -> sparoa::serve::FleetSnapshot
{
    let reg = registry_of(&[
        ("heavy", 8, 6.0, 0.1),
        ("light", 4, 0.3, 0.75),
    ]);
    let heavy = reg.get(0);
    let cap_b = heavy.gpu_batch_cap.max(1);
    let heavy_batch_lat = heavy.latency_us(Proc::Gpu, cap_b).unwrap();
    let heavy_rate = cap_b as f64 / heavy_batch_lat * 1e6;
    let light = reg.get(1);
    let lcap = light.gpu_batch_cap.max(1);
    let light_rate =
        lcap as f64 / light.latency_us(Proc::Gpu, lcap).unwrap() * 1e6;
    let light_lat1 = light.cheapest_latency_us(1).unwrap();
    let cap_w = heavy.gpu_batch_cap.max(heavy.cpu_batch_cap) as f64;
    let classes = vec![
        SloClass::new("interactive", 10.0 * light_lat1, 128,
                      cap_w + 64.0),
        SloClass::new("best-effort", 20.0 * heavy_batch_lat, 512, 1.0),
    ];
    let n_heavy = 400usize;
    let heavy_per_s = 1.8 * 2.0 * heavy_rate;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let light_per_s = 0.2 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(150);
    let tenants = vec![
        Tenant {
            name: "heavy-be".into(),
            model: "heavy".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-int".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 31);
    let opts = FleetOptions {
        router: RouterPolicy::RoundRobin,
        placement: vec![vec![0, 1], vec![0, 1], vec![1]],
        preempt,
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(3, 2)
    };
    run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap()
}

#[test]
fn preempt_and_steal_traces_reconcile_with_counters() {
    for preempt in
        [PreemptionPolicy::DeadlineBurn, PreemptionPolicy::BurnPlusSteal]
    {
        let snap = preempting_fleet(preempt);
        let what = preempt.name();
        let n = snap.aggregate.total_offered();
        assert_eq!(
            snap.aggregate.total_served() + snap.aggregate.total_shed()
                + snap.total_failed(),
            n,
            "{what}: conservation broken"
        );
        let mut preempt_records = 0u64;
        let mut steal_n = 0u64;
        let mut requeues = 0u64;
        for (i, b) in snap.boards.iter().enumerate() {
            assert_eq!(b.trace_dropped, 0,
                       "{what}: board {i} dropped trace records");
            // Preempt events reconcile per board, not just in sum.
            let p = count(&b.trace_events,
                          |e| matches!(e, TraceEvent::Preempt { .. }));
            assert_eq!(p, b.preemptions,
                       "{what}: board {i} Preempt trace vs counter");
            preempt_records += p;
            steal_n += b
                .trace_events
                .iter()
                .map(|r| match r.event {
                    TraceEvent::Steal { n } => n as u64,
                    _ => 0,
                })
                .sum::<u64>();
            requeues += count(&b.trace_events,
                              |e| matches!(e, TraceEvent::Requeue));
            // Capacity identity with retracted busy intervals: a
            // preempted batch's executed prefix stays billed as lane
            // busy time but settles no request, so the wasted lane-us
            // reappear as the snapshot's preempt_waste_us.
            let ph = &b.phases;
            let accounted = ph.service_us() + ph.warmup_us + ph.idle_us
                + b.preempt_waste_us;
            let rel =
                (accounted - ph.capacity_us).abs() / ph.capacity_us;
            assert!(
                rel < 1e-6,
                "{what}: board {i} service {} + warmup {} + idle {} + \
                 waste {} != capacity {} (rel {rel})",
                ph.service_us(), ph.warmup_us, ph.idle_us,
                b.preempt_waste_us, ph.capacity_us
            );
        }
        assert_eq!(preempt_records, snap.total_preemptions(),
                   "{what}: Preempt trace records vs fleet counter");
        assert_eq!(steal_n, snap.total_steals(),
                   "{what}: sum of Steal.n vs fleet counter");
        // No crashes in this run, so every Requeue record is a steal
        // hand-off: exactly one per stolen request, on the victim.
        assert_eq!(requeues, snap.total_steals(),
                   "{what}: Requeue records vs stolen requests");
        match preempt {
            PreemptionPolicy::DeadlineBurn => {
                assert!(snap.total_preemptions() > 0,
                        "overloaded DeadlineBurn run never preempted");
                assert_eq!(snap.total_steals(), 0,
                           "DeadlineBurn must not steal");
            }
            _ => {
                assert!(snap.total_steals() > 0,
                        "idle light-only board was never stolen to");
            }
        }
        // Stolen work dispatches exactly once: QueueWait is the
        // per-request serve marker across the whole fleet.
        let queue_waits: u64 = snap
            .boards
            .iter()
            .map(|b| count(&b.trace_events, |e| {
                matches!(e, TraceEvent::QueueWait { .. })
            }))
            .sum();
        assert_eq!(queue_waits, snap.aggregate.total_served(),
                   "{what}: a request was served zero or multiple times");
    }
}

/// Hedging-friendly traced fleet: heavy + light on all three boards,
/// board 0 thermally stretched through the middle of the run so the
/// detector trips its breaker and deadline-at-risk interactive heads
/// hedge onto the healthy boards.
fn hedging_fleet() -> sparoa::serve::FleetSnapshot {
    let reg = registry_of(&[
        ("heavy", 8, 6.0, 0.1),
        ("light", 4, 0.3, 0.75),
    ]);
    let heavy = reg.get(0);
    let cap_b = heavy.gpu_batch_cap.max(1);
    let heavy_batch_lat = heavy.latency_us(Proc::Gpu, cap_b).unwrap();
    let heavy_rate = cap_b as f64 / heavy_batch_lat * 1e6;
    let light = reg.get(1);
    let lcap = light.gpu_batch_cap.max(1);
    let light_rate =
        lcap as f64 / light.latency_us(Proc::Gpu, lcap).unwrap() * 1e6;
    let light_lat1 = light.cheapest_latency_us(1).unwrap();
    let classes = vec![
        SloClass::new("interactive", 12.0 * light_lat1, 128, 4.0),
        SloClass::new("best-effort", 20.0 * heavy_batch_lat, 512, 1.0),
    ];
    let n_heavy = 300usize;
    let heavy_per_s = 1.0 * 3.0 * heavy_rate;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let light_per_s = 0.6 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(150);
    let tenants = vec![
        Tenant {
            name: "heavy-be".into(),
            model: "heavy".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-int".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 37);
    let horizon = arrivals.last().unwrap().at_us;
    let plan = FaultPlan {
        faults: vec![
            Fault::Thermal {
                board: 0,
                proc: Proc::Gpu,
                at_us: 0.15 * horizon,
                until_us: 0.75 * horizon,
                scale: 2.8,
            },
            Fault::Thermal {
                board: 0,
                proc: Proc::Cpu,
                at_us: 0.15 * horizon,
                until_us: 0.75 * horizon,
                scale: 2.8,
            },
        ],
    };
    let opts = FleetOptions {
        router: RouterPolicy::RoundRobin,
        placement: vec![vec![0, 1]; 3],
        tail: TailPolicy { hedge: true, breaker: true },
        tail_params: TailParams {
            open_cooldown_us: 8_000.0,
            probe_interval_us: 2_000.0,
            ..TailParams::default()
        },
        faults: plan,
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(3, 2)
    };
    run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap()
}

#[test]
fn tail_traces_reconcile_with_counters() {
    let snap = hedging_fleet();
    assert_eq!(
        snap.aggregate.total_served() + snap.aggregate.total_shed()
            + snap.total_failed(),
        snap.aggregate.total_offered(),
        "tail: conservation broken"
    );
    assert!(snap.total_hedges() > 0, "fixture never hedged");
    assert!(snap.total_breaker_opens() > 0,
            "fixture never opened a breaker");
    assert!(snap.total_probes() > 0, "fixture never probed");
    let mut hedge_n = 0u64;
    let mut probe_n = 0u64;
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0,
                   "board {i} dropped trace records");
        // Tail events reconcile per board, not just in sum: the Hedge
        // record lands on the clone's board, the Probe on the probed
        // board, Suspect/BreakerOpen on the gray-failing board.
        let h = count(&b.trace_events,
                      |e| matches!(e, TraceEvent::Hedge));
        assert_eq!(h, b.hedges, "board {i}: Hedge trace vs counter");
        hedge_n += h;
        let p = count(&b.trace_events,
                      |e| matches!(e, TraceEvent::Probe));
        assert_eq!(p, b.probes, "board {i}: Probe trace vs counter");
        probe_n += p;
        assert_eq!(
            count(&b.trace_events,
                  |e| matches!(e, TraceEvent::Suspect)),
            b.suspects,
            "board {i}: Suspect trace vs counter"
        );
        assert_eq!(
            count(&b.trace_events,
                  |e| matches!(e, TraceEvent::BreakerOpen)),
            b.breaker_opens,
            "board {i}: BreakerOpen trace vs counter"
        );
        // Capacity identity grown by the hedge ledger: a cancelled
        // loser's executed prefix (and a duplicate finish's batch
        // share) stays billed as lane busy time but settles nothing —
        // the wasted lane-us reappear as hedge_waste_us.
        let ph = &b.phases;
        let accounted = ph.service_us() + ph.warmup_us + ph.idle_us
            + b.preempt_waste_us + b.hedge_waste_us;
        let rel = (accounted - ph.capacity_us).abs() / ph.capacity_us;
        assert!(
            rel < 1e-6,
            "board {i}: service {} + warmup {} + idle {} + preempt \
             waste {} + hedge waste {} != capacity {} (rel {rel})",
            ph.service_us(), ph.warmup_us, ph.idle_us,
            b.preempt_waste_us, b.hedge_waste_us, ph.capacity_us
        );
    }
    assert_eq!(hedge_n, snap.total_hedges(),
               "Hedge trace records vs fleet counter");
    assert_eq!(probe_n, snap.total_probes(),
               "Probe trace records vs fleet counter");
    // Hedged work still serves exactly once fleet-wide.
    let queue_waits: u64 = snap
        .boards
        .iter()
        .map(|b| count(&b.trace_events, |e| {
            matches!(e, TraceEvent::QueueWait { .. })
        }))
        .sum();
    assert_eq!(queue_waits, snap.aggregate.total_served(),
               "a hedged request was served zero or multiple times");
}
