//! Distributed multi-board serving invariants — always-on (synthetic
//! models + checked-in device profiles; no `make artifacts` gating).
//!
//! Every scenario self-calibrates its arrival rates and deadlines from
//! the registry's memoized latency oracle, so the tests track the
//! synthetic models' real costs instead of hard-coding magic rates:
//!
//! * conservation: every arrival is routed to exactly one board and
//!   settles exactly once (served or shed) under every router, shed
//!   policy and board count;
//! * router ordering: under skewed load (the heavy model pinned to half
//!   the boards), cost-aware routing beats round-robin on aggregate
//!   attainment — the acceptance criterion;
//! * autoscaler convergence: under steady overload the replica count
//!   ramps up, then stabilizes (no scale events in the tail);
//! * autoscaler value: under a diurnal trace the autoscaled fleet sheds
//!   less than a static fleet of the same mean replica count;
//! * the fleet JSON report round-trips, and malformed traces fail with
//!   useful errors instead of panics.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::device::Proc;
use sparoa::graph::ModelGraph;
use sparoa::serve::{
    merge_arrivals, run_fleet, spread_placement, ArrivalPattern,
    AutoscalePolicy, FleetOptions, FleetSnapshot, ModelRegistry,
    RouterPolicy, ShedPolicy, SloClass, Tenant,
};
use sparoa::util::json;

/// heavy = 0, mid = 1, light = 2 (the demo fleet's synthetic shapes).
fn registry3() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in [
        ("heavy", 8, 6.0, 0.1),
        ("mid", 6, 1.5, 0.45),
        ("light", 4, 0.3, 0.75),
    ] {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

/// Per-model calibration: (max req/s of one replica's best lane at the
/// full Alg.2 batch, batch-1 cheapest latency us, full-batch latency us).
fn calibrate(reg: &ModelRegistry, m: usize) -> (f64, f64, f64) {
    let e = reg.get(m);
    let cap = e.gpu_batch_cap.max(1);
    let batch_lat = e.latency_us(Proc::Gpu, cap).unwrap();
    let gpu_rate = cap as f64 / batch_lat * 1e6;
    let ccap = e.cpu_batch_cap.max(1);
    let cpu_batch_lat = e.latency_us(Proc::Cpu, ccap).unwrap();
    let cpu_rate = ccap as f64 / cpu_batch_lat * 1e6;
    let lat1 = e.cheapest_latency_us(1).unwrap();
    (gpu_rate.max(cpu_rate), lat1, batch_lat)
}

/// Interactive / standard / best-effort classes scaled to the heavy
/// model's full-batch latency (so one queued heavy batch endangers an
/// interactive deadline, moderate backlog endangers standard).
fn classes_for(reg: &ModelRegistry) -> Vec<SloClass> {
    let (_, heavy_lat1, heavy_batch) = calibrate(reg, 0);
    let (_, mid_lat1, _) = calibrate(reg, 1);
    let interactive = (1.2 * heavy_batch).max(4.0 * mid_lat1);
    let standard = (3.5 * heavy_batch).max(3.0 * heavy_lat1);
    vec![
        SloClass::new("interactive", interactive, 128, 4.0),
        SloClass::new("standard", standard, 256, 2.0),
        SloClass::new("best-effort", 15.0 * heavy_batch, 512, 1.0),
    ]
}

fn check_conserved(snap: &FleetSnapshot, n_arrivals: usize) {
    assert_eq!(snap.aggregate.total_offered() as usize, n_arrivals,
               "router lost or duplicated requests");
    assert_eq!(
        snap.aggregate.total_served() + snap.aggregate.total_shed(),
        snap.aggregate.total_offered(),
        "fleet conservation broken"
    );
    let board_offered: u64 = snap
        .boards
        .iter()
        .map(|b| b.total_offered())
        .sum();
    assert_eq!(board_offered, snap.aggregate.total_offered(),
               "per-board offered does not sum to aggregate");
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.total_served() + b.total_shed(), b.total_offered(),
                   "board {i} unbalanced");
    }
}

#[test]
fn conservation_across_router_and_boards() {
    let reg = registry3();
    let classes = classes_for(&reg);
    let (heavy_rate, _, _) = calibrate(&reg, 0);
    let (mid_rate, _, _) = calibrate(&reg, 1);
    let routers = [
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::CostAware,
    ];
    let sheds = [
        ShedPolicy::RejectNew,
        ShedPolicy::ShedOldest,
        ShedPolicy::ShedLowestClass,
    ];
    prop::check(
        "fleet-conservation",
        8,
        4242,
        |rng| {
            let nb = 2 + rng.below(3);
            let router = routers[rng.below(3)];
            let shed = sheds[rng.below(3)];
            // Random replica spread, every model covered by
            // construction.
            let reps = [
                1 + rng.below(nb),
                1 + rng.below(nb),
                1 + rng.below(nb),
            ];
            // Overload factor 0.3..2.5x of the hosted capacity.
            let load = rng.range(0.3, 2.5);
            let seed = rng.next_u64() % 10_000;
            (nb, router, shed, reps, load, seed)
        },
        |&(nb, router, shed, reps, load, seed)| {
            let tenants = vec![
                Tenant {
                    name: "heavy-std".into(),
                    model: "heavy".into(),
                    class: 1,
                    pattern: ArrivalPattern::Poisson {
                        rate_per_s: load * heavy_rate * reps[0] as f64,
                        n: 120,
                    },
                },
                Tenant {
                    name: "mid-inter".into(),
                    model: "mid".into(),
                    class: 0,
                    pattern: ArrivalPattern::Mmpp {
                        rate_lo_per_s: 0.05 * mid_rate,
                        rate_hi_per_s: 0.6 * mid_rate * load,
                        mean_dwell_s: 0.05,
                        n: 120,
                    },
                },
                Tenant {
                    name: "light-be".into(),
                    model: "light".into(),
                    class: 2,
                    pattern: ArrivalPattern::Poisson {
                        rate_per_s: load * heavy_rate,
                        n: 80,
                    },
                },
            ];
            let arrivals = merge_arrivals(&tenants, seed);
            let opts = FleetOptions {
                router,
                shed,
                placement: spread_placement(nb, &reps),
                ..FleetOptions::new(nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .map_err(|e| e.to_string())?;
            let n = arrivals.len();
            if snap.aggregate.total_offered() as usize != n {
                return Err(format!(
                    "offered {} != arrivals {n}",
                    snap.aggregate.total_offered()
                ));
            }
            if snap.aggregate.total_served()
                + snap.aggregate.total_shed()
                != snap.aggregate.total_offered()
            {
                return Err("lost requests".into());
            }
            let per_board: u64 = snap
                .boards
                .iter()
                .map(|b| b.total_offered())
                .sum();
            if per_board != snap.aggregate.total_offered() {
                return Err("board/aggregate mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn cost_aware_routing_beats_round_robin_under_skew() {
    // Skew: the heavy model lives only on boards 0 and 1 and keeps
    // their GPUs busy with full batches; interactive mid traffic is
    // hosted everywhere.  Round-robin blindly sends half the
    // interactive stream onto the backlogged heavy boards; cost-aware
    // steers it to the idle ones.
    let reg = registry3();
    let classes = classes_for(&reg);
    let (heavy_rate, _, _) = calibrate(&reg, 0);
    let (mid_rate, _, _) = calibrate(&reg, 1);
    let (light_rate, _, _) = calibrate(&reg, 2);
    let placement = vec![
        vec![0, 1, 2],
        vec![0, 1, 2],
        vec![1, 2],
        vec![1, 2],
    ];
    // Heavy: 85% of its two hosts' combined best-lane capacity.
    let heavy_per_s = 0.85 * 2.0 * heavy_rate;
    let n_heavy = 900usize;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let mid_per_s = 0.05 * 4.0 * mid_rate;
    let light_per_s = 0.015 * 4.0 * light_rate;
    let n_mid = ((mid_per_s * horizon_s) as usize).max(200);
    let n_light = ((light_per_s * horizon_s) as usize).max(120);

    let mut met = std::collections::HashMap::new();
    for router in [RouterPolicy::RoundRobin, RouterPolicy::CostAware] {
        let mut total_met = 0u64;
        for seed in [3u64, 7u64, 11u64] {
            let tenants = vec![
                Tenant {
                    name: "heavy-std".into(),
                    model: "heavy".into(),
                    class: 1,
                    pattern: ArrivalPattern::Poisson {
                        rate_per_s: heavy_per_s,
                        n: n_heavy,
                    },
                },
                Tenant {
                    name: "mid-inter".into(),
                    model: "mid".into(),
                    class: 0,
                    pattern: ArrivalPattern::Poisson {
                        rate_per_s: mid_per_s,
                        n: n_mid,
                    },
                },
                Tenant {
                    name: "light-be".into(),
                    model: "light".into(),
                    class: 2,
                    pattern: ArrivalPattern::Poisson {
                        rate_per_s: light_per_s,
                        n: n_light,
                    },
                },
            ];
            let arrivals = merge_arrivals(&tenants, seed);
            let opts = FleetOptions {
                router,
                placement: placement.clone(),
                ..FleetOptions::new(4, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .unwrap();
            check_conserved(&snap, arrivals.len());
            total_met += snap.aggregate.total_met();
        }
        met.insert(router.name(), total_met);
    }
    assert!(
        met["cost-aware"] > met["round-robin"],
        "cost-aware met {} <= round-robin met {}",
        met["cost-aware"], met["round-robin"]
    );
}

#[test]
fn autoscaler_converges_under_steady_load() {
    // Steady heavy overload needing ~2 replicas from an initial 1: the
    // autoscaler must ramp up early and then hold the replica map
    // steady (no events in the tail, stable timeline).
    let reg = registry3();
    let classes = classes_for(&reg);
    let (heavy_rate, _, _) = calibrate(&reg, 0);
    let (light_rate, _, _) = calibrate(&reg, 2);
    let heavy_per_s = 1.5 * heavy_rate;
    let n_heavy = 1800usize;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    // ~25 control ticks over the run, independent of the models'
    // batch caps.
    let interval_us = horizon_s * 1e6 / 25.0;
    let light_per_s = 0.05 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(150);
    let tenants = vec![
        Tenant {
            name: "heavy-std".into(),
            model: "heavy".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-inter".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 5);
    let opts = FleetOptions {
        placement: vec![
            vec![0, 1, 2],
            vec![2],
            vec![2],
            vec![],
        ],
        autoscale: Some(AutoscalePolicy {
            interval_us,
            warmup_us: 0.5 * interval_us,
            ..Default::default()
        }),
        ..FleetOptions::new(4, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    let ups = snap.scale_events.iter().filter(|e| e.up).count();
    assert!(ups >= 1, "steady overload never scaled up");
    // Convergence = the replica map stabilizes: constant across the
    // whole timeline tail (last quarter of the control ticks).
    assert!(snap.replica_timeline.len() >= 8,
            "timeline too short: {}", snap.replica_timeline.len());
    let tail = (snap.replica_timeline.len() / 4).max(2);
    let last = &snap.replica_timeline[snap.replica_timeline.len() - 1];
    for s in &snap.replica_timeline[snap.replica_timeline.len() - tail..]
    {
        assert_eq!(s.per_model, last.per_model,
                   "replica map still moving in the tail: {:?}",
                   snap.scale_events);
    }
    assert!(last.per_model[0] >= 2,
            "heavy model never gained a second replica");
}

#[test]
fn autoscaled_fleet_sheds_less_than_static_under_diurnal() {
    // Diurnal heavy trace: peak demand needs ~4 replicas, the trough
    // none.  The autoscaler rides the curve; a static fleet pinned at
    // the autoscaled run's mean replica count is peak-underprovisioned
    // and sheds more.
    let reg = registry3();
    let classes = classes_for(&reg);
    let (heavy_rate, _, _) = calibrate(&reg, 0);
    let (light_rate, _, _) = calibrate(&reg, 2);
    let base_per_s = 2.1 * heavy_rate;
    let n_heavy = 4000usize;
    let horizon_s = n_heavy as f64 / base_per_s;
    let period_s = horizon_s / 2.0;
    // ~80 control ticks (40 per diurnal cycle), independent of the
    // models' batch caps.
    let interval_us = horizon_s * 1e6 / 80.0;
    let light_per_s = 0.02 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(150);
    let tenants = vec![
        Tenant {
            name: "heavy-diurnal".into(),
            model: "heavy".into(),
            class: 1,
            pattern: ArrivalPattern::Diurnal {
                base_rate_per_s: base_per_s,
                amplitude: 1.0,
                period_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-inter".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 2);
    let auto_opts = FleetOptions {
        placement: vec![
            vec![0, 1, 2],
            vec![0, 2],
            vec![],
            vec![],
        ],
        autoscale: Some(AutoscalePolicy {
            interval_us,
            warmup_us: 0.5 * interval_us,
            ..Default::default()
        }),
        ..FleetOptions::new(4, 3)
    };
    let auto =
        run_fleet(&reg, &classes, &tenants, &arrivals, &auto_opts)
            .unwrap();
    check_conserved(&auto, arrivals.len());
    assert!(auto.scale_events.iter().any(|e| e.up),
            "diurnal peaks never scaled up");

    // Static fleet at the autoscaled run's mean replica count.
    let static_reps: Vec<usize> = auto
        .mean_replicas
        .iter()
        .map(|&x| (x.round() as usize).clamp(1, 4))
        .collect();
    let static_opts = FleetOptions {
        placement: spread_placement(4, &static_reps),
        ..FleetOptions::new(4, 3)
    };
    let stat =
        run_fleet(&reg, &classes, &tenants, &arrivals, &static_opts)
            .unwrap();
    check_conserved(&stat, arrivals.len());

    assert!(
        auto.total_shed() < stat.total_shed(),
        "autoscaled shed {} (attainment {:.3}, mean replicas {:?}) \
         >= static {:?} shed {} (attainment {:.3})",
        auto.total_shed(),
        auto.aggregate_attainment(),
        auto.mean_replicas,
        static_reps,
        stat.total_shed(),
        stat.aggregate_attainment()
    );
    assert!(
        auto.aggregate_attainment() > stat.aggregate_attainment(),
        "autoscaled attainment {:.3} <= static {:.3}",
        auto.aggregate_attainment(),
        stat.aggregate_attainment()
    );
}

#[test]
fn fleet_json_report_roundtrips() {
    let reg = registry3();
    let classes = classes_for(&reg);
    let (heavy_rate, _, heavy_batch) = calibrate(&reg, 0);
    let tenants = vec![Tenant {
        name: "t".into(),
        model: "heavy".into(),
        class: 1,
        pattern: ArrivalPattern::Poisson {
            rate_per_s: 1.2 * heavy_rate,
            n: 250,
        },
    }];
    let arrivals = merge_arrivals(&tenants, 9);
    let opts = FleetOptions {
        autoscale: Some(AutoscalePolicy {
            interval_us: 3.0 * heavy_batch,
            ..Default::default()
        }),
        ..FleetOptions::new(3, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    let text = snap.to_json_string();
    let v = json::parse(&text).expect("fleet report must parse back");

    // Scalars round-trip exactly.
    assert_eq!(v.str_of("router"), snap.router);
    assert_eq!(v.get("autoscaled").as_bool(), Some(snap.autoscaled));
    assert_eq!(v.get("n_boards").as_usize(), Some(snap.boards.len()));
    assert_eq!(v.get("lanes_cpu").as_usize(), Some(snap.lanes.cpu));
    assert_eq!(v.get("lanes_gpu").as_usize(), Some(snap.lanes.gpu));
    let agg = v.get("aggregate");
    assert!((agg.f64_of("aggregate_attainment")
        - snap.aggregate_attainment())
        .abs()
        < 1e-12);
    assert_eq!(agg.get("offered").as_usize(),
               Some(snap.aggregate.total_offered() as usize));
    assert_eq!(agg.get("served").as_usize(),
               Some(snap.aggregate.total_served() as usize));
    assert_eq!(agg.get("shed").as_usize(),
               Some(snap.total_shed() as usize));
    assert!((v.f64_of("mean_cpu_util") - snap.mean_cpu_util()).abs()
        < 1e-12);
    assert!((v.f64_of("mean_gpu_util") - snap.mean_gpu_util()).abs()
        < 1e-12);

    // Arrays keep their shapes and values.
    let per_board = v.get("per_board").as_arr().unwrap();
    assert_eq!(per_board.len(), snap.boards.len());
    for (pb, b) in per_board.iter().zip(&snap.boards) {
        assert_eq!(pb.get("offered").as_usize(),
                   Some(b.total_offered() as usize));
        assert_eq!(pb.str_of("policy"), b.policy);
    }
    let mean = v.get("mean_replicas").as_arr().unwrap();
    assert_eq!(mean.len(), snap.mean_replicas.len());
    for (jv, x) in mean.iter().zip(&snap.mean_replicas) {
        assert!((jv.as_f64().unwrap() - x).abs() < 1e-12);
    }
    let tl = v.get("replica_timeline").as_arr().unwrap();
    assert_eq!(tl.len(), snap.replica_timeline.len());
    for (jv, s) in tl.iter().zip(&snap.replica_timeline) {
        assert!((jv.f64_of("t_us") - s.t_us).abs() < 1e-9);
        assert_eq!(jv.get("per_model").vec_usize(), s.per_model);
    }
    let ev = v.get("scale_events").as_arr().unwrap();
    assert_eq!(ev.len(), snap.scale_events.len());
    for (jv, e) in ev.iter().zip(&snap.scale_events) {
        assert_eq!(jv.get("model").as_usize(), Some(e.model));
        assert_eq!(jv.get("board").as_usize(), Some(e.board));
        assert_eq!(jv.get("up").as_bool(), Some(e.up));
    }
}

#[test]
fn trace_from_json_rejects_malformed_records_with_context() {
    use sparoa::serve::trace_from_json;
    // A malformed entry names its index instead of panicking or
    // silently truncating the workload.
    let err = trace_from_json("[1.0, \"x\", 3.0]").unwrap_err();
    assert!(format!("{err:#}").contains("entry 1"),
            "unhelpful error: {err:#}");
    // Wrong container shape names the expected key.
    let err = trace_from_json("{\"wrong\": []}").unwrap_err();
    assert!(format!("{err:#}").contains("arrivals_us"),
            "unhelpful error: {err:#}");
    // Garbage input fails in the parser, with context.
    let err = trace_from_json("not json at all").unwrap_err();
    assert!(format!("{err:#}").contains("parsing trace JSON"),
            "unhelpful error: {err:#}");
    // Truncated arrays and wrong scalar types are errors, not panics.
    assert!(trace_from_json("[1.0, 2.0").is_err());
    assert!(trace_from_json("42").is_err());
    assert!(trace_from_json("[]").is_err());
}
