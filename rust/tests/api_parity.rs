//! Backend parity: `SimBackend` and `PjrtBackend` accept the same
//! `Session` configuration and return `InferenceReport`s that agree on
//! every field defined in both — the architectural guarantee that lets
//! figures (simulated) and serving (real) share one engine.

use sparoa::api::{BackendChoice, Session, SessionBuilder};

fn artifacts_ready() -> bool {
    // The parity pair needs real execution: AOT artifacts + the PJRT
    // bridge (`pjrt` cargo feature — the default build is stubbed).
    cfg!(feature = "pjrt")
        && sparoa::artifacts_dir().join("manifest.json").exists()
}

fn build(backend: BackendChoice) -> Session {
    // Deterministic, predictor-free configuration shared by both builds.
    SessionBuilder::new()
        .model("mobilenet_v3_small")
        .device("agx_orin")
        .policy("threshold")
        .batch(1)
        .seed(9)
        .backend(backend)
        .build()
        .unwrap()
}

#[test]
fn sim_and_pjrt_accept_the_same_configuration() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let sim = build(BackendChoice::Sim);
    let real = build(BackendChoice::Pjrt);

    // Identical configuration resolves to the identical schedule.
    assert_eq!(sim.schedule().policy, real.schedule().policy);
    assert_eq!(sim.schedule().xi, real.schedule().xi);
    assert_eq!(sim.backend_name(), "sim");
    assert_eq!(real.backend_name(), "pjrt");

    let srep = sim.infer().unwrap();
    let rrep = real.infer().unwrap();

    // Schedule provenance and batch agree.
    assert_eq!(srep.policy, rrep.policy);
    assert_eq!(srep.batch, rrep.batch);

    // Fields defined in both backends: the shared calibrated timeline.
    assert!((srep.makespan_us - rrep.makespan_us).abs() < 1e-6,
            "sim {} vs pjrt {}", srep.makespan_us, rrep.makespan_us);
    assert!((srep.cpu_busy_us - rrep.cpu_busy_us).abs() < 1e-6);
    assert!((srep.gpu_busy_us - rrep.gpu_busy_us).abs() < 1e-6);
    assert!((srep.transfer_us - rrep.transfer_us).abs() < 1e-6);
    assert!((srep.peak_gpu_mem_mb - rrep.peak_gpu_mem_mb).abs() < 1e-6);
    assert_eq!(srep.switches, rrep.switches);
    assert_eq!(srep.timings.len(), rrep.timings.len());

    // Fields defined only on the real path.
    assert!(srep.output.is_none() && srep.host_us.is_none());
    let out = rrep.output.expect("pjrt returns numerics");
    let last = real.graph().ops.last().unwrap();
    assert_eq!(out.shape, last.exec_out_shape);
    assert!(rrep.host_us.unwrap() > 0.0);
    let sparsity = rrep.measured_sparsity.expect("pjrt measures sparsity");
    assert_eq!(sparsity.len(), real.graph().ops.len());
}

#[test]
fn batched_inference_is_consistent_across_backends() {
    if !artifacts_ready() {
        return;
    }
    let sim = build(BackendChoice::Sim);
    let real = build(BackendChoice::Pjrt);
    let inputs = [real.random_input(1), real.random_input(2)];

    let srep = sim.infer_batch(&inputs).unwrap();
    let rrep = real.infer_batch(&inputs).unwrap();
    assert_eq!(srep.batch, 2);
    assert_eq!(rrep.batch, 2);
    assert!((srep.makespan_us - rrep.makespan_us).abs() < 1e-6);
    // The real backend executed both items.
    assert!(rrep.host_us.unwrap() > 0.0);
    assert!(rrep.output.is_some());
}
