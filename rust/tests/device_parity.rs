//! Parity between the rust device simulator and its python mirror
//! (python/compile/device_model.py), which labels the predictor's ground
//! truth.  Drift between the two would silently invalidate Table 3.
//!
//! Requires `python` on PATH (skips cleanly otherwise).

use sparoa::device::{DeviceRegistry, Proc};
use sparoa::graph::OpClass;

fn python_latencies(cases: &[(&str, &str, f64, f64, f64)]) -> Option<Vec<f64>> {
    let mut script = String::from(
        "import sys, json\n\
         sys.path.insert(0, 'python')\n\
         from compile import device_model as dm\n\
         cfg = dm.load('config/devices.json')\n\
         out = []\n",
    );
    for (dev, class, flops, bytes, sp) in cases {
        script.push_str(&format!(
            "out.append(dm.op_latency_us(cfg['devices']['{dev}'], 'cpu', \
             '{class}', {flops}, {bytes}, {sp}))\n\
             out.append(dm.op_latency_us(cfg['devices']['{dev}'], 'gpu', \
             '{class}', {flops}, {bytes}, {sp}))\n"
        ));
    }
    script.push_str("print(json.dumps(out))\n");
    let out = std::process::Command::new("python")
        .arg("-c")
        .arg(&script)
        .current_dir(sparoa::repo_root())
        .output()
        .ok()?;
    if !out.status.success() {
        eprintln!(
            "python mirror failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        return None;
    }
    let text = String::from_utf8(out.stdout).ok()?;
    let v = sparoa::util::json::parse(text.trim()).ok()?;
    Some(v.vec_f64())
}

#[test]
fn roofline_matches_python_mirror() {
    let cases: Vec<(&str, &str, f64, f64, f64)> = vec![
        ("agx_orin", "conv", 2e9, 1e7, 0.0),
        ("agx_orin", "conv", 2e9, 1e7, 0.7),
        ("agx_orin", "matmul", 5e8, 4e6, 0.3),
        ("agx_orin", "norm", 1e5, 8e5, 0.0),
        ("agx_orin", "elementwise", 5e4, 4e5, 0.9),
        ("orin_nano", "dwconv", 1e8, 2e6, 0.5),
        ("orin_nano", "attention", 3e9, 5e7, 0.1),
        ("orin_nano", "pool", 1e6, 1e6, 0.0),
        ("orin_nano", "softmax", 2e6, 1.5e6, 0.0),
    ];
    let Some(py) = python_latencies(&cases) else {
        eprintln!("python unavailable; skipping parity test");
        return;
    };
    let reg =
        DeviceRegistry::load(&sparoa::repo_root().join("config/devices.json"))
            .unwrap();
    for (i, (dev, class, flops, bytes, sp)) in cases.iter().enumerate() {
        let d = reg.get(dev).unwrap();
        let class = OpClass::parse(class).unwrap();
        for (j, proc) in [Proc::Cpu, Proc::Gpu].into_iter().enumerate() {
            let rust = d.op_latency_us(proc, class, *flops, *bytes, *sp);
            let python = py[i * 2 + j];
            let rel = (rust - python).abs() / python.max(1e-9);
            assert!(
                rel < 1e-9,
                "case {i} {dev}/{class:?}/{proc:?}: rust={rust} py={python}"
            );
        }
    }
}

#[test]
fn transfer_matches_python_mirror() {
    let script = "import sys, json\n\
        sys.path.insert(0, 'python')\n\
        from compile import device_model as dm\n\
        cfg = dm.load('config/devices.json')\n\
        d = cfg['devices']['agx_orin']\n\
        print(json.dumps([dm.transfer_us(d, 1e6), \
                          dm.transfer_us(d, 1e6, pinned=False), \
                          dm.transfer_us(d, 1e6, overlap=True)]))\n";
    let Ok(out) = std::process::Command::new("python")
        .arg("-c")
        .arg(script)
        .current_dir(sparoa::repo_root())
        .output()
    else {
        return;
    };
    if !out.status.success() {
        eprintln!("python mirror unavailable; skipping");
        return;
    }
    let py = sparoa::util::json::parse(
        String::from_utf8(out.stdout).unwrap().trim(),
    )
    .unwrap()
    .vec_f64();
    let reg =
        DeviceRegistry::load(&sparoa::repo_root().join("config/devices.json"))
            .unwrap();
    let d = reg.get("agx_orin").unwrap();
    let rust = [
        d.transfer_us(1e6, true, false),
        d.transfer_us(1e6, false, false),
        d.transfer_us(1e6, true, true),
    ];
    for (r, p) in rust.iter().zip(&py) {
        assert!((r - p).abs() / p < 1e-9, "rust {r} vs py {p}");
    }
}
