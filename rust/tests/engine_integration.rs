//! End-to-end engine integration: every exported model runs through the
//! real PJRT path under every scheduling mode, produces the right shapes,
//! finite numerics, and sparsity statistics consistent with the build-time
//! profile.

use sparoa::engine::HybridEngine;
use sparoa::graph::ModelZoo;
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::Schedule;
use sparoa::util::rng::Rng;

fn setup() -> Option<(ModelZoo, Runtime)> {
    let art = sparoa::artifacts_dir();
    if !art.join("manifest.json").exists() {
        eprintln!("artifacts missing; skipping");
        return None;
    }
    Some((ModelZoo::load(&art).unwrap(), Runtime::new(&art).unwrap()))
}

fn random_input(shape: &[usize], seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let n: usize = shape.iter().product();
    HostTensor::new(shape.to_vec(), (0..n).map(|_| rng.normal() as f32)
        .collect())
}

#[test]
fn all_models_execute_end_to_end() {
    let Some((zoo, rt)) = setup() else { return };
    for (name, g) in &zoo.graphs {
        let engine = HybridEngine::new(&rt, g).unwrap();
        let input = random_input(&g.input_shape_exec, 42);
        let sched = Schedule::uniform(g, 1.0, "gpu");
        let res = engine.infer(&input, &sched).unwrap();
        let last = g.ops.last().unwrap();
        assert_eq!(res.output.shape, last.exec_out_shape, "{name}");
        assert!(
            res.output.data.iter().all(|v| v.is_finite()),
            "{name}: non-finite output"
        );
    }
}

#[test]
fn schedule_does_not_change_numerics() {
    // Placement is a performance decision; results must be identical.
    let Some((zoo, rt)) = setup() else { return };
    let g = zoo.get("mobilenet_v3_small").unwrap();
    let engine = HybridEngine::new(&rt, g).unwrap();
    let input = random_input(&g.input_shape_exec, 7);
    let gpu = engine
        .infer(&input, &Schedule::uniform(g, 1.0, "gpu"))
        .unwrap();
    let cpu = engine
        .infer(&input, &Schedule::uniform(g, 0.0, "cpu"))
        .unwrap();
    let corun = engine
        .infer(&input, &Schedule::uniform(g, 0.5, "co"))
        .unwrap();
    assert_eq!(gpu.output.data, cpu.output.data);
    for (a, b) in gpu.output.data.iter().zip(&corun.output.data) {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0));
    }
}

#[test]
fn measured_sparsity_consistent_with_profile() {
    // The build-time topology sparsity came from the python interpreter;
    // the rust engine's measured sparsity on a fresh input should agree
    // closely for ReLU outputs (exact-zero producers).
    let Some((zoo, rt)) = setup() else { return };
    let g = zoo.get("resnet18").unwrap();
    let engine = HybridEngine::new(&rt, g).unwrap();
    let input = random_input(&g.input_shape_exec, 1234);
    let res = engine
        .infer(&input, &Schedule::uniform(g, 1.0, "gpu"))
        .unwrap();
    let mut checked = 0;
    for op in &g.ops {
        if matches!(op.kind, sparoa::graph::OpKind::Relu)
            && op.sparsity_out > 0.2
        {
            let measured = res.sparsity_out[op.id];
            assert!(
                (measured - op.sparsity_out).abs() < 0.15,
                "{}: measured {measured} vs profiled {}",
                op.name,
                op.sparsity_out
            );
            checked += 1;
        }
    }
    assert!(checked > 5, "too few ReLU ops checked: {checked}");
}

#[test]
fn warm_up_compiles_everything_once() {
    let Some((zoo, rt)) = setup() else { return };
    let g = zoo.get("swin_t").unwrap();
    let engine = HybridEngine::new(&rt, g).unwrap();
    let n = engine.warm_up().unwrap();
    assert!(n > 100, "swin_t should have >100 artifact ops, got {n}");
}
