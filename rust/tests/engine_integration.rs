//! End-to-end engine integration through the public `api::Session`
//! surface: every exported model runs through the real PJRT backend under
//! every scheduling mode, produces the right shapes, finite numerics, and
//! sparsity statistics consistent with the build-time profile.

use sparoa::api::{BackendChoice, Session, SessionBuilder};
use sparoa::graph::ModelZoo;
use sparoa::scheduler::Schedule;

fn artifacts_ready() -> bool {
    // Real execution needs both the AOT artifacts and the PJRT bridge
    // (`pjrt` cargo feature — the default build ships a stub runtime).
    cfg!(feature = "pjrt")
        && sparoa::artifacts_dir().join("manifest.json").exists()
}

fn pjrt_session(model: &str) -> Session {
    SessionBuilder::new()
        .model(model)
        .policy("gpu")
        .backend(BackendChoice::Pjrt)
        .build()
        .unwrap()
}

#[test]
fn all_models_execute_end_to_end() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let zoo = ModelZoo::load(&sparoa::artifacts_dir()).unwrap();
    for (name, _) in &zoo.graphs {
        let session = pjrt_session(name);
        let rep = session
            .infer_input(&session.random_input(42))
            .unwrap();
        let last = session.graph().ops.last().unwrap();
        let out = rep.output.expect("pjrt returns numerics");
        assert_eq!(out.shape, last.exec_out_shape, "{name}");
        assert!(
            out.data.iter().all(|v| v.is_finite()),
            "{name}: non-finite output"
        );
        assert_eq!(rep.backend, "pjrt", "{name}");
        assert!(rep.host_us.unwrap_or(0.0) > 0.0, "{name}");
    }
}

#[test]
fn schedule_does_not_change_numerics() {
    // Placement is a performance decision; results must be identical.
    if !artifacts_ready() {
        return;
    }
    let mut session = pjrt_session("mobilenet_v3_small");
    let input = session.random_input(7);
    let gpu = session.infer_input(&input).unwrap().output.unwrap();
    session.set_schedule(Schedule::uniform(session.graph(), 0.0, "cpu"));
    let cpu = session.infer_input(&input).unwrap().output.unwrap();
    session.set_schedule(Schedule::uniform(session.graph(), 0.5, "co"));
    let corun = session.infer_input(&input).unwrap().output.unwrap();
    assert_eq!(gpu.data, cpu.data);
    for (a, b) in gpu.data.iter().zip(&corun.data) {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0));
    }
}

#[test]
fn measured_sparsity_consistent_with_profile() {
    // The build-time topology sparsity came from the python interpreter;
    // the rust engine's measured sparsity on a fresh input should agree
    // closely for ReLU outputs (exact-zero producers).
    if !artifacts_ready() {
        return;
    }
    let session = pjrt_session("resnet18");
    let rep = session
        .infer_input(&session.random_input(1234))
        .unwrap();
    let measured = rep.measured_sparsity.expect("pjrt measures sparsity");
    let mut checked = 0;
    for op in &session.graph().ops {
        if matches!(op.kind, sparoa::graph::OpKind::Relu)
            && op.sparsity_out > 0.2
        {
            assert!(
                (measured[op.id] - op.sparsity_out).abs() < 0.15,
                "{}: measured {} vs profiled {}",
                op.name,
                measured[op.id],
                op.sparsity_out
            );
            checked += 1;
        }
    }
    assert!(checked > 5, "too few ReLU ops checked: {checked}");
}

#[test]
fn warm_up_compiles_everything_once() {
    if !artifacts_ready() {
        return;
    }
    let session = pjrt_session("swin_t");
    // SessionBuilder::build warms the backend up; the compiled count is
    // reported on the session.
    assert!(session.compiled() > 100,
            "swin_t should have >100 artifact ops, got {}",
            session.compiled());
}
