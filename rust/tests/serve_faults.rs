//! Fault-injection / failover invariants for the fleet — always-on
//! (synthetic models + checked-in device profiles; no `make artifacts`
//! gating).
//!
//! * bit-identity: with [`FaultPlan::none`] the fault machinery is
//!   never armed and the fleet report is byte-identical to the default
//!   path, with or without the failover flag;
//! * conservation: under randomized fault plans (crashes with and
//!   without rejoin, lane loss, thermal windows) crossed with every
//!   shed policy, admitted == served + shed + failed exactly — no
//!   request is ever silently lost;
//! * quarantine: the router never dispatches work on a board between
//!   its crash and its rejoin, and the rejoined board resumes serving;
//! * exactly-once: every served request has exactly one `QueueWait`
//!   trace record, so drained/retried requests are never double-served;
//! * failover value: on an 8-board fleet with a seeded mid-run crash,
//!   failover (requeue + deadline-aware retry) beats the
//!   failover-disabled control on SLO attainment — the acceptance
//!   criterion.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::device::Proc;
use sparoa::faults::{Fault, FaultPlan};
use sparoa::graph::ModelGraph;
use sparoa::obs::{TraceConfig, TraceEvent};
use sparoa::serve::{
    merge_arrivals, run_fleet, ArrivalPattern, FleetOptions,
    FleetSnapshot, ModelRegistry, PreemptionPolicy, ShedPolicy,
    SloClass, Tenant,
};

/// heavy = 0, mid = 1, light = 2 (the demo fleet's synthetic shapes).
fn registry3() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in [
        ("heavy", 8, 6.0, 0.1),
        ("mid", 6, 1.5, 0.45),
        ("light", 4, 0.3, 0.75),
    ] {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

/// Per-model calibration: (max req/s of one replica's best lane at the
/// full Alg.2 batch, batch-1 cheapest latency us, full-batch latency us).
fn calibrate(reg: &ModelRegistry, m: usize) -> (f64, f64, f64) {
    let e = reg.get(m);
    let cap = e.gpu_batch_cap.max(1);
    let batch_lat = e.latency_us(Proc::Gpu, cap).unwrap();
    let gpu_rate = cap as f64 / batch_lat * 1e6;
    let ccap = e.cpu_batch_cap.max(1);
    let cpu_batch_lat = e.latency_us(Proc::Cpu, ccap).unwrap();
    let cpu_rate = ccap as f64 / cpu_batch_lat * 1e6;
    let lat1 = e.cheapest_latency_us(1).unwrap();
    (gpu_rate.max(cpu_rate), lat1, batch_lat)
}

/// Interactive / standard / best-effort classes scaled to the heavy
/// model's full-batch latency (same shape as `serve_fleet.rs`).
fn classes_for(reg: &ModelRegistry) -> Vec<SloClass> {
    let (_, heavy_lat1, heavy_batch) = calibrate(reg, 0);
    let (_, mid_lat1, _) = calibrate(reg, 1);
    let interactive = (1.2 * heavy_batch).max(4.0 * mid_lat1);
    let standard = (3.5 * heavy_batch).max(3.0 * heavy_lat1);
    vec![
        SloClass::new("interactive", interactive, 128, 4.0),
        SloClass::new("standard", standard, 256, 2.0),
        SloClass::new("best-effort", 15.0 * heavy_batch, 512, 1.0),
    ]
}

/// Fault-aware conservation: every arrival settles exactly once as
/// served, shed or failed.  (Per-board balance deliberately not
/// asserted: a request offered to a crashing board may settle on the
/// survivor it was re-placed on.)
fn check_conserved(snap: &FleetSnapshot, n_arrivals: usize) {
    assert_eq!(snap.aggregate.total_offered() as usize, n_arrivals,
               "fleet lost or duplicated requests at admission");
    assert_eq!(
        snap.aggregate.total_served()
            + snap.aggregate.total_shed()
            + snap.total_failed(),
        snap.aggregate.total_offered(),
        "conservation broken: served {} + shed {} + failed {} != \
         offered {}",
        snap.aggregate.total_served(),
        snap.aggregate.total_shed(),
        snap.total_failed(),
        snap.aggregate.total_offered()
    );
}

/// The standard three-tenant stream used by every scenario here:
/// heavy/standard + mid/interactive + light/best-effort Poisson
/// streams sized to `frac` of the fleet's per-model hosted capacity.
fn tenants_at(
    reg: &ModelRegistry,
    hosts: usize,
    frac: f64,
    n_heavy: usize,
) -> Vec<Tenant> {
    let (heavy_rate, _, _) = calibrate(reg, 0);
    let (mid_rate, _, _) = calibrate(reg, 1);
    let (light_rate, _, _) = calibrate(reg, 2);
    let heavy_per_s = frac * hosts as f64 * heavy_rate;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let mid_per_s = 0.18 * hosts as f64 * mid_rate;
    let light_per_s = 0.05 * hosts as f64 * light_rate;
    let n_mid = ((mid_per_s * horizon_s) as usize).max(80);
    let n_light = ((light_per_s * horizon_s) as usize).max(60);
    vec![
        Tenant {
            name: "heavy-std".into(),
            model: "heavy".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "mid-inter".into(),
            model: "mid".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: mid_per_s,
                n: n_mid,
            },
        },
        Tenant {
            name: "light-be".into(),
            model: "light".into(),
            class: 2,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ]
}

/// All three models warm on every one of `nb` boards, so a single
/// crash always leaves survivors hosting every model.
fn all_on_all(nb: usize) -> Vec<Vec<usize>> {
    vec![vec![0, 1, 2]; nb]
}

#[test]
fn fault_free_plan_is_bit_identical_to_default_path() {
    // FaultPlan::none() must arm nothing: the report is byte-identical
    // whether the plan (or the failover ablation flag) is spelled out
    // or left at the default, and no fault counters leak into it.
    let reg = registry3();
    let classes = classes_for(&reg);
    let tenants = tenants_at(&reg, 3, 0.8, 300);
    let arrivals = merge_arrivals(&tenants, 17);
    let run = |faults: FaultPlan, failover: bool| {
        let opts = FleetOptions {
            faults,
            failover,
            ..FleetOptions::new(3, 3)
        };
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
            .unwrap()
            .to_json_string()
    };
    let baseline = run(FaultPlan::none(), true);
    assert_eq!(baseline, run(FaultPlan::none(), true),
               "fleet run is not deterministic");
    assert_eq!(baseline, run(FaultPlan::none(), false),
               "failover flag changed a fault-free run");
    assert!(!baseline.contains("failovers"),
            "fault counters leaked into a fault-free report");
    assert!(!baseline.contains("downtime_us"),
            "downtime leaked into a fault-free report");
}

#[test]
fn conservation_is_exact_under_randomized_fault_plans() {
    #[derive(Debug)]
    struct Case {
        nb: usize,
        shed: ShedPolicy,
        load: f64,
        seed: u64,
        failover: bool,
        crash_board: usize,
        crash_frac: f64,
        rejoin: bool,
        lane_loss: bool,
        lane_board: usize,
        lane_gpu: bool,
        lane_restore: bool,
        thermal: bool,
        thermal_scale: f64,
    }
    let reg = registry3();
    let classes = classes_for(&reg);
    let sheds = [
        ShedPolicy::RejectNew,
        ShedPolicy::ShedOldest,
        ShedPolicy::ShedLowestClass,
    ];
    prop::check(
        "fault-conservation",
        10,
        20_260_807,
        |rng| Case {
            nb: 2 + rng.below(3),
            shed: sheds[rng.below(3)],
            load: rng.range(0.4, 1.8),
            seed: rng.next_u64() % 10_000,
            failover: rng.below(2) == 0,
            crash_board: rng.below(16),
            crash_frac: rng.range(0.15, 0.6),
            rejoin: rng.below(2) == 0,
            lane_loss: rng.below(2) == 0,
            lane_board: rng.below(16),
            lane_gpu: rng.below(2) == 0,
            lane_restore: rng.below(2) == 0,
            thermal: rng.below(2) == 0,
            thermal_scale: rng.range(1.2, 2.5),
        },
        |c| {
            let tenants = tenants_at(&reg, c.nb, c.load, 150);
            let arrivals = merge_arrivals(&tenants, c.seed);
            let horizon =
                arrivals.last().map_or(1.0, |a| a.at_us).max(1.0);
            let mut faults = vec![Fault::Crash {
                board: c.crash_board % c.nb,
                at_us: c.crash_frac * horizon,
                rejoin_us: c
                    .rejoin
                    .then_some((c.crash_frac + 0.25) * horizon),
            }];
            if c.lane_loss {
                faults.push(Fault::LaneLoss {
                    board: c.lane_board % c.nb,
                    proc: if c.lane_gpu { Proc::Gpu } else { Proc::Cpu },
                    at_us: 0.2 * horizon,
                    restore_us: c.lane_restore.then_some(0.6 * horizon),
                });
            }
            if c.thermal {
                faults.push(Fault::Thermal {
                    board: (c.crash_board + 1) % c.nb,
                    proc: Proc::Gpu,
                    at_us: 0.1 * horizon,
                    until_us: 0.5 * horizon,
                    scale: c.thermal_scale,
                });
            }
            let opts = FleetOptions {
                shed: c.shed,
                placement: all_on_all(c.nb),
                faults: FaultPlan { faults },
                failover: c.failover,
                ..FleetOptions::new(c.nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .map_err(|e| e.to_string())?;
            let n = arrivals.len() as u64;
            if snap.aggregate.total_offered() != n {
                return Err(format!(
                    "offered {} != arrivals {n}",
                    snap.aggregate.total_offered()
                ));
            }
            let settled = snap.aggregate.total_served()
                + snap.aggregate.total_shed()
                + snap.total_failed();
            if settled != n {
                return Err(format!(
                    "conservation broken: served {} + shed {} + \
                     failed {} = {settled} != {n}",
                    snap.aggregate.total_served(),
                    snap.aggregate.total_shed(),
                    snap.total_failed()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn crashed_board_is_quarantined_then_resumes() {
    // One crash/rejoin on board 1, plan supplied as JSON (the CLI
    // path), tracing on.  Between BoardDown and BoardUp the board must
    // never dispatch; after rejoin it must serve again; every served
    // request must have exactly one QueueWait record (drained/retried
    // work is never double-served).
    let reg = registry3();
    let classes = classes_for(&reg);
    let nb = 4;
    let tenants = tenants_at(&reg, nb, 0.65, 1200);
    let arrivals = merge_arrivals(&tenants, 11);
    let horizon = arrivals.last().unwrap().at_us;
    let (crash_us, rejoin_us) = (0.4 * horizon, 0.7 * horizon);
    let plan = FaultPlan::from_json(&format!(
        r#"[{{"kind": "crash", "board": 1, "at_us": {crash_us},
             "rejoin_us": {rejoin_us}}}]"#
    ))
    .unwrap();
    let opts = FleetOptions {
        placement: all_on_all(nb),
        trace: Some(TraceConfig::default()),
        faults: plan,
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert_eq!(snap.total_failovers(), 1, "exactly one crash was armed");
    assert!(
        (snap.total_downtime_us() - (rejoin_us - crash_us)).abs() < 1.0,
        "downtime {} != scheduled window {}",
        snap.total_downtime_us(),
        rejoin_us - crash_us
    );

    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0, "board {i} dropped trace records");
    }
    let crashed = &snap.boards[1];
    let t_down = crashed
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BoardDown)
        .expect("BoardDown was traced")
        .t_us;
    let t_up = crashed
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BoardUp)
        .expect("BoardUp was traced")
        .t_us;
    assert!(t_down < t_up, "down at {t_down} not before up at {t_up}");
    let dispatched_while_down = crashed.trace_events.iter().any(|r| {
        matches!(r.event, TraceEvent::Dispatch { .. })
            && r.t_us > t_down
            && r.t_us < t_up
    });
    assert!(!dispatched_while_down,
            "router dispatched onto a down board");
    let resumed = crashed.trace_events.iter().any(|r| {
        matches!(r.event, TraceEvent::Dispatch { .. }) && r.t_us > t_up
    });
    assert!(resumed, "rejoined board never dispatched again");

    // The crash had teeth: it stranded queued and/or in-flight work.
    assert!(
        snap.total_requeued() + snap.aggregate.lost_batches > 0,
        "crash stranded nothing (requeued {}, lost batches {})",
        snap.total_requeued(),
        snap.aggregate.lost_batches
    );
    let requeue_records = crashed
        .trace_events
        .iter()
        .filter(|r| r.event == TraceEvent::Requeue)
        .count() as u64;
    assert_eq!(requeue_records, snap.total_requeued(),
               "Requeue trace records disagree with the counter");

    // Served exactly once: QueueWait is the per-request serve marker.
    let queue_waits: u64 = snap
        .boards
        .iter()
        .map(|b| {
            b.trace_events
                .iter()
                .filter(|r| {
                    matches!(r.event, TraceEvent::QueueWait { .. })
                })
                .count() as u64
        })
        .sum();
    assert_eq!(queue_waits, snap.aggregate.total_served(),
               "a request was served zero or multiple times");
}

#[test]
fn failover_beats_no_failover_after_a_mid_run_crash() {
    // The acceptance scenario: 8 boards, a seeded single-board crash
    // mid-run with late rejoin.  With failover the crashed board's
    // queued work re-places onto survivors and lost in-flight batches
    // get deadline-aware retries; the control fails every stranded
    // request on the spot.  Both conserve exactly; failover must win
    // on served-within-deadline.
    let reg = registry3();
    let classes = classes_for(&reg);
    let nb = 8;
    let mut met = std::collections::HashMap::new();
    let mut fo_requeued = 0u64;
    for failover in [true, false] {
        let mut total_met = 0u64;
        for seed in [3u64, 7u64, 11u64] {
            let tenants = tenants_at(&reg, nb, 0.7, 1400);
            let arrivals = merge_arrivals(&tenants, seed);
            let horizon = arrivals.last().unwrap().at_us;
            let plan = FaultPlan {
                faults: vec![Fault::Crash {
                    board: 3,
                    at_us: 0.45 * horizon,
                    rejoin_us: Some(0.8 * horizon),
                }],
            };
            let opts = FleetOptions {
                placement: all_on_all(nb),
                faults: plan,
                failover,
                ..FleetOptions::new(nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .unwrap();
            check_conserved(&snap, arrivals.len());
            assert_eq!(snap.total_failovers(), 1);
            if failover {
                fo_requeued += snap.total_requeued();
            } else {
                // The control never re-places or retries anything.
                assert_eq!(snap.total_retries(), 0);
            }
            total_met += snap.aggregate.total_met();
        }
        met.insert(failover, total_met);
    }
    assert!(fo_requeued > 0,
            "crash never stranded queued work across 3 seeds");
    assert!(
        met[&true] > met[&false],
        "failover met {} <= no-failover met {}",
        met[&true], met[&false]
    );
}

/// Classes where voluntary preemption can actually fire: the
/// interactive weight outranks a *full* best-effort batch (the burn
/// check only cancels a victim whose still-meetable weight is below
/// the rescued class weight, and a full batch of weight-1 requests
/// totals batch-cap) and the interactive deadline sits far below a
/// heavy batch's runtime so queued heads genuinely burn behind one.
fn classes_rescue(reg: &ModelRegistry) -> Vec<SloClass> {
    let (_, heavy_lat1, heavy_batch) = calibrate(reg, 0);
    let (_, light_lat1, _) = calibrate(reg, 2);
    let cap_w = reg.get(0).gpu_batch_cap.max(reg.get(0).cpu_batch_cap)
        as f64;
    vec![
        SloClass::new("interactive", 10.0 * light_lat1, 128,
                      cap_w + 64.0),
        SloClass::new(
            "standard",
            (3.5 * heavy_batch).max(3.0 * heavy_lat1),
            256,
            2.0,
        ),
        SloClass::new("best-effort", 20.0 * heavy_batch, 512, 1.0),
    ]
}

#[test]
fn crash_racing_preemption_settles_exactly_once() {
    // Preemption × faults interaction: a seeded crash lands inside an
    // active preemption window (the overloaded run preempts
    // continuously from the start) with BurnPlusSteal armed.  Drained,
    // retried, preempted AND stolen requests must all settle exactly
    // once — the in-flight ledger is shared between the crash and
    // preempt retract paths, so a batch cancelled by one must be
    // invisible to the other — and the quarantined board must never be
    // a steal destination while down.
    let reg = registry3();
    let classes = classes_rescue(&reg);
    let nb = 4;
    // Heavy best-effort flood at 1.8x hosted capacity pins lanes with
    // long weight-1 batches; a light interactive trickle burns behind
    // them.
    let (heavy_rate, _, _) = calibrate(&reg, 0);
    let (light_rate, _, _) = calibrate(&reg, 2);
    let n_heavy = 450usize;
    let heavy_per_s = 1.8 * nb as f64 * heavy_rate;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let light_per_s = 0.10 * nb as f64 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(120);
    let tenants = vec![
        Tenant {
            name: "heavy-be".into(),
            model: "heavy".into(),
            class: 2,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-int".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, 19);
    let horizon = arrivals.last().unwrap().at_us;
    let (crash_us, rejoin_us) = (0.45 * horizon, 0.75 * horizon);
    let plan = FaultPlan {
        faults: vec![Fault::Crash {
            board: 1,
            at_us: crash_us,
            rejoin_us: Some(rejoin_us),
        }],
    };
    let opts = FleetOptions {
        preempt: PreemptionPolicy::BurnPlusSteal,
        placement: all_on_all(nb),
        trace: Some(TraceConfig::default()),
        faults: plan,
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert_eq!(snap.total_failovers(), 1, "exactly one crash was armed");
    assert!(snap.total_preemptions() > 0,
            "overloaded run never preempted — the race is vacuous");
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0, "board {i} dropped trace records");
    }
    // The crash really raced preemption churn: cancellations happened
    // before the scheduled crash instant.
    let preempts_before = snap
        .boards
        .iter()
        .flat_map(|b| b.trace_events.iter())
        .any(|r| {
            matches!(r.event, TraceEvent::Preempt { .. })
                && r.t_us < crash_us
        });
    assert!(preempts_before, "no preemption fired before the crash");

    // Served exactly once: QueueWait is the per-request serve marker,
    // covering drained, retried, preempted and stolen requests alike.
    let queue_waits: u64 = snap
        .boards
        .iter()
        .map(|b| {
            b.trace_events
                .iter()
                .filter(|r| {
                    matches!(r.event, TraceEvent::QueueWait { .. })
                })
                .count() as u64
        })
        .sum();
    assert_eq!(queue_waits, snap.aggregate.total_served(),
               "a request was served zero or multiple times");

    // Quarantine: a down board is excluded from steal destinations, so
    // nothing may dispatch on it between its down and up markers.
    let crashed = &snap.boards[1];
    let t_down = crashed
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BoardDown)
        .expect("BoardDown was traced")
        .t_us;
    let t_up = crashed
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BoardUp)
        .expect("BoardUp was traced")
        .t_us;
    let dispatched_while_down = crashed.trace_events.iter().any(|r| {
        matches!(r.event, TraceEvent::Dispatch { .. })
            && r.t_us > t_down
            && r.t_us < t_up
    });
    assert!(!dispatched_while_down,
            "work was stolen onto (or dispatched by) a down board");
}
