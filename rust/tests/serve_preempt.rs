//! Differential scheduling invariants for cross-board preemption +
//! work re-placement — always-on (synthetic models + checked-in device
//! profiles; no `make artifacts` gating).
//!
//! * bit-stability: `PreemptionPolicy::Off` runs are byte-identical to
//!   the default path (and deterministic), and no preempt counters
//!   leak into their JSON;
//! * conservation: randomized workloads × all three policies × all
//!   three routers keep `offered == served + shed + failed` exact with
//!   preemptions and steals active (the per-request settled-set
//!   `debug_assert` inside the board additionally panics the test
//!   binary if any preempted request were ever settled twice);
//! * exactly-once: every served request has exactly one `QueueWait`
//!   trace record even on runs where batches were preempted and work
//!   was stolen between boards;
//! * energy: the per-board energy ledger still equals the
//!   busy-interval trace integral after preemption retracts/refunds
//!   (the `serve_energy.rs` reconciliation, now with retired batches);
//! * value: `DeadlineBurn` strictly beats `Off` on interactive-class
//!   attainment under overload across 3 seeds — the acceptance
//!   criterion;
//! * pend-heap × steal race: a mid-run crash (drain + re-pend + retry)
//!   concurrent with `BurnPlusSteal` stealing still settles every
//!   request exactly once — stealing only moves work owned by a
//!   board's admission queues, never the fleet's pend heap.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::device::Proc;
use sparoa::faults::{Fault, FaultPlan};
use sparoa::graph::ModelGraph;
use sparoa::obs::{TraceConfig, TraceEvent};
use sparoa::power::{Governor, PowerConfig, PowerProfile};
use sparoa::serve::{
    merge_arrivals, run_fleet, ArrivalPattern, FleetOptions,
    FleetSnapshot, ModelRegistry, PerfSnapshot, PreemptionPolicy,
    RouterPolicy, SloClass, Tenant,
};

/// heavy = 0, mid = 1, light = 2 (the demo fleet's synthetic shapes).
fn registry3() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in [
        ("heavy", 8, 6.0, 0.1),
        ("mid", 6, 1.5, 0.45),
        ("light", 4, 0.3, 0.75),
    ] {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

/// Per-model calibration: (max req/s of one replica's best lane at the
/// full Alg.2 batch, batch-1 cheapest latency us, full-batch latency us).
fn calibrate(reg: &ModelRegistry, m: usize) -> (f64, f64, f64) {
    let e = reg.get(m);
    let cap = e.gpu_batch_cap.max(1);
    let batch_lat = e.latency_us(Proc::Gpu, cap).unwrap();
    let gpu_rate = cap as f64 / batch_lat * 1e6;
    let ccap = e.cpu_batch_cap.max(1);
    let cpu_batch_lat = e.latency_us(Proc::Cpu, ccap).unwrap();
    let cpu_rate = ccap as f64 / cpu_batch_lat * 1e6;
    let lat1 = e.cheapest_latency_us(1).unwrap();
    (gpu_rate.max(cpu_rate), lat1, batch_lat)
}

/// Classes tuned so preemption has teeth.  The interactive deadline
/// sits far below a heavy best-effort batch's runtime (so an
/// interactive head genuinely burns behind one), and the interactive
/// weight outranks a *full* best-effort batch: the burn check only
/// cancels a victim whose still-meetable weight (at most batch-cap ×
/// 1.0) is below the rescued class weight.
fn classes_preempt(reg: &ModelRegistry) -> Vec<SloClass> {
    let (_, heavy_lat1, heavy_batch) = calibrate(reg, 0);
    let (_, light_lat1, _) = calibrate(reg, 2);
    let cap_w = reg.get(0).gpu_batch_cap.max(reg.get(0).cpu_batch_cap)
        as f64;
    vec![
        SloClass::new("interactive", 10.0 * light_lat1, 128,
                      cap_w + 64.0),
        SloClass::new(
            "standard",
            (3.5 * heavy_batch).max(3.0 * heavy_lat1),
            256,
            2.0,
        ),
        SloClass::new("best-effort", 20.0 * heavy_batch, 512, 1.0),
    ]
}

/// The preemption stress mix: a heavy best-effort flood at `frac` of
/// the fleet's hosted capacity (long weight-1 batches that pin lanes)
/// plus a light interactive trickle whose tight deadlines burn behind
/// them.
fn overload_tenants(
    reg: &ModelRegistry,
    hosts: usize,
    frac: f64,
    n_heavy: usize,
) -> Vec<Tenant> {
    let (heavy_rate, _, _) = calibrate(reg, 0);
    let (light_rate, _, _) = calibrate(reg, 2);
    let heavy_per_s = frac * hosts as f64 * heavy_rate;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let light_per_s = 0.10 * hosts as f64 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(120);
    vec![
        Tenant {
            name: "heavy-be".into(),
            model: "heavy".into(),
            class: 2,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-int".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ]
}

/// All three models warm on every board: steals and crash failovers
/// always have an eligible destination.
fn all_on_all(nb: usize) -> Vec<Vec<usize>> {
    vec![vec![0, 1, 2]; nb]
}

fn check_conserved(snap: &FleetSnapshot, n_arrivals: usize) {
    assert_eq!(snap.aggregate.total_offered() as usize, n_arrivals,
               "fleet lost or duplicated requests at admission");
    assert_eq!(
        snap.aggregate.total_served()
            + snap.aggregate.total_shed()
            + snap.total_failed(),
        snap.aggregate.total_offered(),
        "conservation broken: served {} + shed {} + failed {} != \
         offered {}",
        snap.aggregate.total_served(),
        snap.aggregate.total_shed(),
        snap.total_failed(),
        snap.aggregate.total_offered()
    );
}

#[test]
fn off_policy_is_byte_stable_and_leaks_no_preempt_keys() {
    // `Off` must arm nothing: the report is byte-identical whether the
    // policy is spelled out or left at the default, the run is
    // deterministic, and no preempt counters appear in its JSON.
    let reg = registry3();
    let classes = classes_preempt(&reg);
    let tenants = overload_tenants(&reg, 3, 1.2, 220);
    let arrivals = merge_arrivals(&tenants, 17);
    let run = |preempt: PreemptionPolicy| {
        let opts = FleetOptions {
            preempt,
            placement: all_on_all(3),
            ..FleetOptions::new(3, 3)
        };
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
            .unwrap()
            .to_json_string()
    };
    let default_opts = FleetOptions {
        placement: all_on_all(3),
        ..FleetOptions::new(3, 3)
    };
    let baseline =
        run_fleet(&reg, &classes, &tenants, &arrivals, &default_opts)
            .unwrap()
            .to_json_string();
    assert_eq!(baseline, run(PreemptionPolicy::Off),
               "explicit Off differs from the default path");
    assert_eq!(baseline, run(PreemptionPolicy::Off),
               "Off run is not deterministic");
    assert!(!baseline.contains("preemptions"),
            "preempt counters leaked into an Off report");
    assert!(!baseline.contains("preempt_waste_us"),
            "preempt waste leaked into an Off report");
    assert!(!baseline.contains("\"steals\""),
            "steal counters leaked into an Off report");
}

#[test]
fn conservation_exact_across_policies_and_routers() {
    #[derive(Debug)]
    struct Case {
        nb: usize,
        router: RouterPolicy,
        preempt: PreemptionPolicy,
        frac: f64,
        seed: u64,
    }
    let reg = registry3();
    let classes = classes_preempt(&reg);
    let routers = [
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::CostAware,
    ];
    let policies = [
        PreemptionPolicy::Off,
        PreemptionPolicy::DeadlineBurn,
        PreemptionPolicy::BurnPlusSteal,
    ];
    let mut preempting_runs = 0usize;
    prop::check(
        "preempt-conservation",
        9,
        20_260_807,
        |rng| Case {
            nb: 2 + rng.below(3),
            router: routers[rng.below(3)],
            preempt: policies[rng.below(3)],
            frac: rng.range(0.8, 2.2),
            seed: rng.next_u64() % 10_000,
        },
        |c| {
            let tenants = overload_tenants(&reg, c.nb, c.frac, 150);
            let arrivals = merge_arrivals(&tenants, c.seed);
            let opts = FleetOptions {
                router: c.router,
                preempt: c.preempt,
                placement: all_on_all(c.nb),
                ..FleetOptions::new(c.nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .map_err(|e| e.to_string())?;
            let n = arrivals.len() as u64;
            if snap.aggregate.total_offered() != n {
                return Err(format!(
                    "offered {} != arrivals {n}",
                    snap.aggregate.total_offered()
                ));
            }
            let settled = snap.aggregate.total_served()
                + snap.aggregate.total_shed()
                + snap.total_failed();
            if settled != n {
                return Err(format!(
                    "conservation broken: served {} + shed {} + \
                     failed {} = {settled} != {n}",
                    snap.aggregate.total_served(),
                    snap.aggregate.total_shed(),
                    snap.total_failed()
                ));
            }
            // Policy gating: counters only move when armed.
            match c.preempt {
                PreemptionPolicy::Off => {
                    if snap.total_preemptions() != 0
                        || snap.total_steals() != 0
                        || snap.total_preempt_waste_us() != 0.0
                    {
                        return Err("Off run preempted or stole".into());
                    }
                }
                PreemptionPolicy::DeadlineBurn => {
                    if snap.total_steals() != 0 {
                        return Err(
                            "DeadlineBurn run stole work".into());
                    }
                }
                PreemptionPolicy::BurnPlusSteal => {}
            }
            if snap.total_preemptions() > 0 {
                preempting_runs += 1;
            }
            Ok(())
        },
    );
    assert!(preempting_runs > 0,
            "no randomized case ever preempted — the suite is vacuous");
}

#[test]
fn preempting_run_serves_every_request_exactly_once() {
    // Exactly-once under preemption: QueueWait is the per-request
    // serve marker; a preempted-then-requeued request must produce
    // exactly one, and the run must actually preempt to count.
    let reg = registry3();
    let classes = classes_preempt(&reg);
    let nb = 3;
    let tenants = overload_tenants(&reg, nb, 1.8, 400);
    let arrivals = merge_arrivals(&tenants, 11);
    let opts = FleetOptions {
        preempt: PreemptionPolicy::DeadlineBurn,
        placement: all_on_all(nb),
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert!(snap.total_preemptions() > 0,
            "overload run never preempted");
    assert!(snap.total_preempt_waste_us() > 0.0,
            "preemptions reported but no waste accrued");
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0, "board {i} dropped trace records");
    }
    let queue_waits: u64 = snap
        .boards
        .iter()
        .map(|b| {
            b.trace_events
                .iter()
                .filter(|r| {
                    matches!(r.event, TraceEvent::QueueWait { .. })
                })
                .count() as u64
        })
        .sum();
    assert_eq!(queue_waits, snap.aggregate.total_served(),
               "a request was served zero or multiple times");
}

#[test]
fn energy_ledger_reconciles_after_preemption_retracts() {
    // The serve_energy.rs reconciliation, now with retracted batches:
    // BoardPower::retract must refund the cancelled tail from both the
    // ledger and the busy-interval trace so they still agree exactly.
    let reg = registry3();
    let classes = classes_preempt(&reg);
    let nb = 3;
    let tenants = overload_tenants(&reg, nb, 1.8, 350);
    let arrivals = merge_arrivals(&tenants, 29);
    let profile =
        PowerProfile::from_device(&device_profile("agx_orin")).unwrap();
    let mut pc = PowerConfig::new(profile, Governor::RaceToIdle);
    pc.trace = true;
    let opts = FleetOptions {
        preempt: PreemptionPolicy::DeadlineBurn,
        placement: all_on_all(nb),
        power: Some(pc),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert!(snap.total_preemptions() > 0,
            "no preemption fired — the retract path went unexercised");
    for (i, board) in snap.boards.iter().enumerate() {
        assert_eq!(board.power_trace_dropped, 0,
                   "board {i} dropped busy intervals — raise trace_cap");
        let busy_mj: f64 = board
            .power_trace
            .iter()
            .map(|e| e.busy_w * (e.finish_us - e.start_us))
            .sum::<f64>()
            / 1e3;
        if busy_mj > 0.0 {
            let rel = (board.busy_energy_mj - busy_mj).abs()
                / busy_mj.abs().max(1e-12);
            assert!(rel < 1e-6,
                    "board {i} busy ledger {} != trace {busy_mj}",
                    board.busy_energy_mj);
        }
        let integral = integrate_board(board);
        let denom =
            board.energy_mj.abs().max(integral.abs()).max(1e-12);
        assert!(
            ((board.energy_mj - integral) / denom).abs() < 1e-6,
            "board {i} energy {} != integral {integral}",
            board.energy_mj
        );
    }
}

/// Integrate one board's power timeline from its busy-interval trace
/// (same reconstruction as `serve_energy.rs`).  Returns mJ.
fn integrate_board(snap: &PerfSnapshot) -> f64 {
    let over_floor: f64 = snap
        .power_trace
        .iter()
        .map(|e| (e.busy_w - e.idle_w) * (e.finish_us - e.start_us))
        .sum();
    (over_floor + (snap.idle_floor_w + snap.soc_w)
        * snap.power_horizon_us)
        / 1e3
}

#[test]
fn deadline_burn_beats_off_on_high_class_attainment() {
    // The acceptance scenario: under overload, cancelling weight-1
    // best-effort batches must strictly lift interactive attainment
    // over run-to-completion, across 3 seeds.
    let reg = registry3();
    let classes = classes_preempt(&reg);
    let nb = 4;
    let mut hi_met = std::collections::HashMap::new();
    let mut burn_preemptions = 0u64;
    for preempt in [PreemptionPolicy::Off, PreemptionPolicy::DeadlineBurn]
    {
        let mut met = 0u64;
        for seed in [3u64, 7u64, 11u64] {
            let tenants = overload_tenants(&reg, nb, 1.8, 500);
            let arrivals = merge_arrivals(&tenants, seed);
            let opts = FleetOptions {
                preempt,
                placement: all_on_all(nb),
                ..FleetOptions::new(nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .unwrap();
            check_conserved(&snap, arrivals.len());
            if preempt.preempts() {
                burn_preemptions += snap.total_preemptions();
            } else {
                assert_eq!(snap.total_preemptions(), 0);
            }
            met += snap.aggregate.per_class[0].met;
        }
        hi_met.insert(preempt.name(), met);
    }
    assert!(burn_preemptions > 0,
            "DeadlineBurn never fired across 3 overload seeds");
    assert!(
        hi_met["deadline-burn"] > hi_met["off"],
        "DeadlineBurn interactive met {} <= Off {}",
        hi_met["deadline-burn"], hi_met["off"]
    );
}

#[test]
fn pend_heap_and_steal_race_settles_exactly_once() {
    // Regression for the drain/steal double-count risk: a mid-run
    // crash drains a board's queues into the fleet pend heap (and
    // retries its lost batches) while BurnPlusSteal keeps stealing
    // queued work between survivor boards.  Ownership must stay
    // exclusive — every request settles exactly once and conservation
    // stays exact.
    let reg = registry3();
    let classes = classes_preempt(&reg);
    let nb = 4;
    let tenants = overload_tenants(&reg, nb, 1.6, 500);
    let arrivals = merge_arrivals(&tenants, 13);
    let horizon = arrivals.last().unwrap().at_us;
    let plan = FaultPlan {
        faults: vec![Fault::Crash {
            board: 1,
            at_us: 0.4 * horizon,
            rejoin_us: Some(0.7 * horizon),
        }],
    };
    let opts = FleetOptions {
        preempt: PreemptionPolicy::BurnPlusSteal,
        placement: all_on_all(nb),
        faults: plan,
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert_eq!(snap.total_failovers(), 1);
    assert!(snap.total_requeued() + snap.aggregate.lost_batches > 0,
            "crash stranded nothing — the race never happened");
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0, "board {i} dropped trace records");
    }
    let queue_waits: u64 = snap
        .boards
        .iter()
        .map(|b| {
            b.trace_events
                .iter()
                .filter(|r| {
                    matches!(r.event, TraceEvent::QueueWait { .. })
                })
                .count() as u64
        })
        .sum();
    assert_eq!(queue_waits, snap.aggregate.total_served(),
               "a request was served zero or multiple times");
    // Quarantine: the crashed board is never a steal destination (no
    // Dispatch lands on it between its down and up markers).
    let crashed = &snap.boards[1];
    let t_down = crashed
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BoardDown)
        .expect("BoardDown was traced")
        .t_us;
    let t_up = crashed
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BoardUp)
        .expect("BoardUp was traced")
        .t_us;
    let dispatched_while_down = crashed.trace_events.iter().any(|r| {
        matches!(r.event, TraceEvent::Dispatch { .. })
            && r.t_us > t_down
            && r.t_us < t_up
    });
    assert!(!dispatched_while_down,
            "work was stolen onto (or dispatched by) a down board");
}
