//! End-to-end serving integration through `api::Session`: real PJRT
//! execution + virtual-time batching over a Poisson request stream (the
//! same path examples/serve_requests.rs demonstrates).

use sparoa::api::{BackendChoice, SessionBuilder};
use sparoa::server::{batcher::poisson_stream, BatchPolicy, ServeMetrics};

fn artifacts_ready() -> bool {
    sparoa::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn serves_real_requests_through_pjrt() {
    // Needs the PJRT bridge (`pjrt` cargo feature) on top of artifacts.
    if !cfg!(feature = "pjrt") || !artifacts_ready() {
        return;
    }
    let session = SessionBuilder::new()
        .model("mobilenet_v3_small")
        .device("agx_orin")
        .policy("greedy")
        .backend(BackendChoice::Pjrt)
        .build()
        .unwrap();

    let mut metrics = ServeMetrics::new();
    for seed in 0..8u64 {
        let input = session.random_input(seed);
        let t0 = std::time::Instant::now();
        let rep = session.infer_input(&input).unwrap();
        metrics.record(t0.elapsed().as_secs_f64() * 1e6);
        let out = rep.output.expect("pjrt returns numerics");
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
    metrics.finish();
    assert_eq!(metrics.count(), 8);
    assert!(metrics.throughput_rps() > 0.0);
    println!("{}", metrics.summary("real-exec"));
}

#[test]
fn dynamic_batching_wins_across_rates_and_devices() {
    // Fig. 8's claim at integration scope: SparOA's dynamic batching keeps
    // overhead below the static fixed-batch policy at every arrival rate
    // on both device profiles.  Runs on the synthetic fixture so it never
    // silently skips; artifact models only sharpen the numbers.
    for dev_name in ["agx_orin", "orin_nano"] {
        let mut builder = SessionBuilder::new()
            .device(dev_name)
            .policy("gpu")
            .backend(BackendChoice::Sim);
        builder = if artifacts_ready() {
            builder.model("mobilenet_v3_small")
        } else {
            builder.with_graph(sparoa::graph::ModelGraph::synthetic(
                "fig8_fixture", 6, 1.0, 0.5))
        };
        let session = builder.build().unwrap();
        for rate in [50.0, 200.0, 800.0] {
            let reqs = poisson_stream(250, rate, 11);
            let fixed = session
                .serve(&reqs, &BatchPolicy::Fixed {
                    size: 32, timeout_us: 25_000.0 })
                .unwrap();
            let dynamic = session
                .serve(&reqs, &BatchPolicy::Dynamic {
                    max: 64, optimizer_cost_us: 30.0 })
                .unwrap();
            assert!(
                dynamic.overhead_pct() <= fixed.overhead_pct() + 1.0,
                "{dev_name}@{rate}: dyn {:.1}% vs fixed {:.1}%",
                dynamic.overhead_pct(),
                fixed.overhead_pct()
            );
            assert!(dynamic.p99_latency_us <= fixed.p99_latency_us * 1.5);
        }
    }
}
