//! End-to-end serving integration: real PJRT execution + virtual-time
//! batching over a Poisson request stream (the same path
//! examples/serve_requests.rs demonstrates).

use sparoa::device::DeviceRegistry;
use sparoa::engine::sim::SimOptions;
use sparoa::engine::HybridEngine;
use sparoa::graph::ModelZoo;
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::{greedy::GreedyScheduler, ScheduleCtx, Scheduler};
use sparoa::server::{
    batcher::poisson_stream, run_batching_sim, BatchPolicy, ServeMetrics,
};
use sparoa::util::rng::Rng;

#[test]
fn serves_real_requests_through_pjrt() {
    let art = sparoa::artifacts_dir();
    if !art.join("manifest.json").exists() {
        return;
    }
    let zoo = ModelZoo::load(&art).unwrap();
    let g = zoo.get("mobilenet_v3_small").unwrap();
    let rt = Runtime::new(&art).unwrap();
    let engine = HybridEngine::new(&rt, g).unwrap();
    engine.warm_up().unwrap();
    let reg = DeviceRegistry::load(
        &sparoa::repo_root().join("config/devices.json")).unwrap();
    let dev = reg.get("agx_orin").unwrap();
    let plan = GreedyScheduler.schedule(&ScheduleCtx {
        graph: g, device: dev, thresholds: None, batch: 1,
    });

    let mut metrics = ServeMetrics::new();
    let mut rng = Rng::new(5);
    let n: usize = g.input_shape_exec.iter().product();
    for _ in 0..8 {
        let input = HostTensor::new(
            g.input_shape_exec.clone(),
            (0..n).map(|_| rng.normal() as f32).collect(),
        );
        let t0 = std::time::Instant::now();
        let out = engine.infer(&input, &plan).unwrap();
        metrics.record(t0.elapsed().as_secs_f64() * 1e6);
        assert!(out.output.data.iter().all(|v| v.is_finite()));
    }
    metrics.finish();
    assert_eq!(metrics.count(), 8);
    assert!(metrics.throughput_rps() > 0.0);
    println!("{}", metrics.summary("real-exec"));
}

#[test]
fn dynamic_batching_wins_across_rates_and_devices() {
    // Fig. 8's claim at integration scope: SparOA's dynamic batching keeps
    // overhead below the static fixed-batch policy at every arrival rate
    // on both device profiles.
    let art = sparoa::artifacts_dir();
    if !art.join("manifest.json").exists() {
        return;
    }
    let zoo = ModelZoo::load(&art).unwrap();
    let reg = DeviceRegistry::load(
        &sparoa::repo_root().join("config/devices.json")).unwrap();
    let g = zoo.get("mobilenet_v3_small").unwrap();
    for dev_name in ["agx_orin", "orin_nano"] {
        let dev = reg.get(dev_name).unwrap();
        let sched = sparoa::scheduler::Schedule::uniform(g, 1.0, "gpu");
        for rate in [50.0, 200.0, 800.0] {
            let reqs = poisson_stream(250, rate, 11);
            let fixed = run_batching_sim(
                g, dev, &sched, &SimOptions::default(), &reqs,
                &BatchPolicy::Fixed { size: 32, timeout_us: 25_000.0 });
            let dynamic = run_batching_sim(
                g, dev, &sched, &SimOptions::default(), &reqs,
                &BatchPolicy::Dynamic { max: 64, optimizer_cost_us: 30.0 });
            assert!(
                dynamic.overhead_pct() <= fixed.overhead_pct() + 1.0,
                "{dev_name}@{rate}: dyn {:.1}% vs fixed {:.1}%",
                dynamic.overhead_pct(),
                fixed.overhead_pct()
            );
            assert!(dynamic.p99_latency_us <= fixed.p99_latency_us * 1.5);
        }
    }
}
