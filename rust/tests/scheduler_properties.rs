//! Property-based tests on coordinator invariants (bench_support::prop —
//! the vendored crate set has no proptest; same seeded-generation model).

use sparoa::bench_support::prop;
use sparoa::device::DeviceRegistry;
use sparoa::engine::sim::{simulate, SimOptions};
use sparoa::graph::ModelZoo;
use sparoa::scheduler::{
    dp::DpScheduler, greedy::GreedyScheduler, primary_proc,
    threshold::ThresholdScheduler, Schedule, ScheduleCtx, Scheduler,
};
use sparoa::util::rng::Rng;

fn setup() -> Option<(ModelZoo, DeviceRegistry)> {
    let art = sparoa::artifacts_dir();
    if !art.join("manifest.json").exists() {
        eprintln!("artifacts missing; skipping");
        return None;
    }
    Some((
        ModelZoo::load(&art).unwrap(),
        DeviceRegistry::load(&sparoa::repo_root().join("config/devices.json"))
            .unwrap(),
    ))
}

/// Random schedule generator over a model's ops.
fn random_schedule(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.f64()).collect()
}

#[test]
fn prop_simulation_invariants_under_random_schedules() {
    let Some((zoo, reg)) = setup() else { return };
    let models: Vec<&str> = zoo.graphs.keys().map(|s| s.as_str()).collect();
    prop::check(
        "sim-invariants",
        60,
        42,
        |rng| {
            let m = models[rng.below(models.len())].to_string();
            let n = zoo.get(&m).unwrap().ops.len();
            let dev = if rng.below(2) == 0 { "agx_orin" } else { "orin_nano" };
            (m, dev.to_string(), random_schedule(rng, n),
             1 + rng.below(16))
        },
        |(m, dev, xi, batch)| {
            let g = zoo.get(m).unwrap();
            let d = reg.get(dev).unwrap();
            let sched = Schedule { xi: xi.clone(), policy: "rand".into() };
            let r = simulate(g, d, &sched,
                             &SimOptions { batch: *batch,
                                           ..Default::default() });
            if !(r.makespan_us > 0.0) {
                return Err(format!("non-positive makespan {}", r.makespan_us));
            }
            let parts = r.cpu_busy_us + r.gpu_busy_us + r.transfer_us
                + r.aggregation_us;
            if r.makespan_us > parts + 1e-6 {
                return Err(format!(
                    "makespan {} exceeds busy sum {parts}", r.makespan_us));
            }
            for v in [r.cpu_busy_us, r.gpu_busy_us, r.transfer_us,
                      r.launch_us, r.aggregation_us, r.peak_gpu_mem_mb] {
                if !(v >= 0.0) || !v.is_finite() {
                    return Err(format!("negative/NaN component {v}"));
                }
            }
            // per-op timings are causally ordered and within the makespan
            for t in &r.timings {
                if t.finish_us < t.start_us
                    || t.finish_us > r.makespan_us + 1e-6
                {
                    return Err(format!("op {} timing out of range", t.op));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedulers_emit_valid_ratios() {
    let Some((zoo, reg)) = setup() else { return };
    let models: Vec<&str> = zoo.graphs.keys().map(|s| s.as_str()).collect();
    prop::check(
        "valid-ratios",
        12,
        7,
        |rng| {
            (models[rng.below(models.len())].to_string(),
             1 + rng.below(8))
        },
        |(m, batch)| {
            let g = zoo.get(m).unwrap();
            let dev = reg.get("agx_orin").unwrap();
            let ctx = ScheduleCtx { graph: g, device: dev,
                                    thresholds: None, batch: *batch };
            for plan in [
                GreedyScheduler.schedule(&ctx),
                DpScheduler { ensemble: 2 }.schedule(&ctx),
                ThresholdScheduler.schedule(&ctx),
            ] {
                if plan.xi.len() != g.ops.len() {
                    return Err("wrong schedule length".into());
                }
                for (i, &x) in plan.xi.iter().enumerate() {
                    if !(0.0..=1.0).contains(&x) {
                        return Err(format!("xi[{i}]={x} out of range"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dp_no_worse_than_greedy_or_single_device() {
    let Some((zoo, reg)) = setup() else { return };
    let models: Vec<&str> = zoo.graphs.keys().map(|s| s.as_str()).collect();
    prop::check(
        "dp-quality",
        10,
        11,
        |rng| {
            (models[rng.below(models.len())].to_string(),
             if rng.below(2) == 0 { "agx_orin" } else { "orin_nano" }
                 .to_string())
        },
        |(m, dev)| {
            let g = zoo.get(m).unwrap();
            let d = reg.get(dev).unwrap();
            let ctx = ScheduleCtx { graph: g, device: d, thresholds: None,
                                    batch: 1 };
            let opts = SimOptions::default();
            let dp = simulate(g, d, &DpScheduler { ensemble: 4 }
                              .schedule(&ctx), &opts).makespan_us;
            let cpu = simulate(g, d, &Schedule::uniform(g, 0.0, "c"),
                               &opts).makespan_us;
            let gpu = simulate(g, d, &Schedule::uniform(g, 1.0, "g"),
                               &opts).makespan_us;
            if dp > cpu.min(gpu) * 1.05 {
                return Err(format!(
                    "dp {dp} worse than best single device {}",
                    cpu.min(gpu)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gpu_share_and_switches_consistent() {
    let Some((zoo, _reg)) = setup() else { return };
    let g = zoo.get("resnet18").unwrap();
    prop::check(
        "share-switches",
        100,
        3,
        |rng| random_schedule(rng, g.ops.len()),
        |xi| {
            let s = Schedule { xi: xi.clone(), policy: "r".into() };
            let share = s.gpu_share(g);
            if !(0.0..=1.0).contains(&share) {
                return Err(format!("share {share}"));
            }
            let n_sched = g.schedulable_ops().count();
            let gpu_count = g
                .schedulable_ops()
                .filter(|o| primary_proc(xi[o.id]) == sparoa::device::Proc::Gpu)
                .count();
            if (share - gpu_count as f64 / n_sched as f64).abs() > 1e-9 {
                return Err("share mismatch".into());
            }
            if s.switch_count(g) >= n_sched {
                return Err("more switches than ops".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparsity_never_hurts_in_simulator() {
    // With sparse-aware kernels on, higher input sparsity can only lower
    // (or keep) each op's simulated cost -> whole-model makespan is
    // monotone non-increasing in a global sparsity boost.
    let Some((zoo, reg)) = setup() else { return };
    let g = zoo.get("mobilenet_v2").unwrap();
    let dev = reg.get("agx_orin").unwrap();
    prop::check(
        "sparsity-monotone",
        30,
        9,
        |rng| random_schedule(rng, g.ops.len()),
        |xi| {
            let sched = Schedule { xi: xi.clone(), policy: "r".into() };
            let base = simulate(g, dev, &sched, &SimOptions::default());
            let off = simulate(g, dev, &sched, &SimOptions {
                sparsity_aware: false,
                ..Default::default()
            });
            if base.makespan_us > off.makespan_us * 1.0001 {
                return Err(format!(
                    "sparsity-aware slower: {} vs {}",
                    base.makespan_us, off.makespan_us));
            }
            Ok(())
        },
    );
}
