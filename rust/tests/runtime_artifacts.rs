//! Integration: PJRT runtime loads and executes real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise).

use sparoa::graph::{ModelZoo, OpKind};
use sparoa::runtime::{HostTensor, Runtime, WeightStore};
use sparoa::util::rng::Rng;

fn artifacts_ready() -> bool {
    // Real execution needs both the AOT artifacts and the PJRT bridge
    // (`pjrt` cargo feature — the default build ships a stub runtime).
    cfg!(feature = "pjrt")
        && sparoa::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn executes_first_conv_of_mobilenet() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let zoo = ModelZoo::load(&sparoa::artifacts_dir()).unwrap();
    let g = zoo.get("mobilenet_v3_small").unwrap();
    let ws = WeightStore::load(&g.weights_path).unwrap();
    let rt = Runtime::new(&sparoa::artifacts_dir()).unwrap();

    let conv = g
        .ops
        .iter()
        .find(|o| o.kind == OpKind::Conv2d)
        .expect("model has a conv");
    let mut rng = Rng::new(1);
    let x = HostTensor::new(
        conv.exec_in_shapes[0].clone(),
        (0..conv.exec_in_shapes[0].iter().product::<usize>())
            .map(|_| rng.normal() as f32)
            .collect(),
    );
    let mut args = vec![x];
    args.extend(ws.op_params(conv).unwrap());
    let out = rt
        .execute(conv.artifact.as_ref().unwrap(), &args)
        .unwrap();
    assert_eq!(out.shape, conv.exec_out_shape);
    assert!(out.data.iter().all(|v| v.is_finite()));
}

#[test]
fn relu_artifact_matches_native() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let zoo = ModelZoo::load(&sparoa::artifacts_dir()).unwrap();
    let g = zoo.get("resnet18").unwrap();
    let rt = Runtime::new(&sparoa::artifacts_dir()).unwrap();
    let relu = g
        .ops
        .iter()
        .find(|o| o.kind == OpKind::Relu)
        .expect("model has a relu");
    let n: usize = relu.exec_in_shapes[0].iter().product();
    let mut rng = Rng::new(2);
    let x = HostTensor::new(
        relu.exec_in_shapes[0].clone(),
        (0..n).map(|_| rng.normal() as f32).collect(),
    );
    let out = rt.execute(relu.artifact.as_ref().unwrap(), &[x.clone()]).unwrap();
    for (o, i) in out.data.iter().zip(&x.data) {
        assert_eq!(*o, i.max(0.0));
    }
    // ReLU on zero-mean noise: ~half the outputs are exactly zero.
    assert!(out.sparsity() > 0.4 && out.sparsity() < 0.6);
}

#[test]
fn executable_cache_reuses_compilations() {
    if !artifacts_ready() {
        return;
    }
    let zoo = ModelZoo::load(&sparoa::artifacts_dir()).unwrap();
    let g = zoo.get("resnet18").unwrap();
    let rt = Runtime::new(&sparoa::artifacts_dir()).unwrap();
    let n = rt.warm_up(g).unwrap();
    assert!(n > 50, "resnet18 should have >50 artifact-backed ops, got {n}");
    let cached = rt.cached();
    rt.warm_up(g).unwrap();
    assert_eq!(rt.cached(), cached, "second warm-up must not recompile");
}
