//! Differential invariants for the tail-tolerance extension —
//! gray-failure detection, circuit-breaker probation and hedged
//! dispatch — always-on (synthetic models + checked-in device
//! profiles; no `make artifacts` gating).
//!
//! * bit-stability: `--hedge=off --breaker=off` runs are byte-identical
//!   to the default path (fault-free and under a thermal plan), and no
//!   tail counters leak into their JSON;
//! * conservation: randomized thermal gray-failure plans × routers ×
//!   hedge/breaker settings keep `offered == served + shed + failed`
//!   exact, with a non-vacuity guard that hedges AND breaker opens
//!   actually fired across the sample (the per-request settled-set
//!   `debug_assert` inside the board additionally panics the test
//!   binary if any request were ever settled twice);
//! * exactly-once: a hedge racing a board crash, and a hedge racing
//!   batch preemption, still serve every request at most once
//!   (`QueueWait` is the per-request serve marker);
//! * probation: a breaker-open board admits only probe dispatches
//!   until its breaker closes;
//! * energy: the per-board energy ledger still equals the
//!   busy-interval trace integral after hedge cancels retract and
//!   refund in-flight loser batches.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::device::Proc;
use sparoa::faults::{Fault, FaultPlan};
use sparoa::graph::ModelGraph;
use sparoa::obs::{TraceConfig, TraceEvent};
use sparoa::power::{Governor, PowerConfig, PowerProfile};
use sparoa::serve::{
    merge_arrivals, run_fleet, ArrivalPattern, FleetOptions,
    FleetSnapshot, ModelRegistry, PreemptionPolicy, RouterPolicy,
    SloClass, TailParams, TailPolicy, Tenant,
};

/// heavy = 0, mid = 1, light = 2 (the demo fleet's synthetic shapes).
fn registry3() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in [
        ("heavy", 8, 6.0, 0.1),
        ("mid", 6, 1.5, 0.45),
        ("light", 4, 0.3, 0.75),
    ] {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

/// Per-model calibration: (max req/s of one replica's best lane at the
/// full Alg.2 batch, batch-1 cheapest latency us, full-batch latency).
fn calibrate(reg: &ModelRegistry, m: usize) -> (f64, f64, f64) {
    let e = reg.get(m);
    let cap = e.gpu_batch_cap.max(1);
    let batch_lat = e.latency_us(Proc::Gpu, cap).unwrap();
    let gpu_rate = cap as f64 / batch_lat * 1e6;
    let ccap = e.cpu_batch_cap.max(1);
    let cpu_batch_lat = e.latency_us(Proc::Cpu, ccap).unwrap();
    let cpu_rate = ccap as f64 / cpu_batch_lat * 1e6;
    let lat1 = e.cheapest_latency_us(1).unwrap();
    (gpu_rate.max(cpu_rate), lat1, batch_lat)
}

/// Classes tuned so hedges have teeth: the interactive deadline is a
/// modest multiple of the light model's batch-1 latency, so a queue
/// forming behind a thermally-stretched board genuinely puts heads
/// at risk while a healthy twin board can still save them.
fn classes_tail(reg: &ModelRegistry) -> Vec<SloClass> {
    let (_, heavy_lat1, heavy_batch) = calibrate(reg, 0);
    let (_, light_lat1, _) = calibrate(reg, 2);
    vec![
        SloClass::new("interactive", 12.0 * light_lat1, 128, 4.0),
        SloClass::new(
            "standard",
            (3.5 * heavy_batch).max(3.0 * heavy_lat1),
            256,
            2.0,
        ),
        SloClass::new("best-effort", 20.0 * heavy_batch, 512, 1.0),
    ]
}

/// The gray-failure stress mix: a heavy best-effort stream near the
/// fleet's hosted capacity (keeps lanes busy so the detector sees a
/// steady sample stream) plus a light interactive stream whose tight
/// deadlines go at-risk behind a thermally-stretched board.
fn tail_tenants(
    reg: &ModelRegistry,
    hosts: usize,
    frac: f64,
    n_heavy: usize,
) -> Vec<Tenant> {
    let (heavy_rate, _, _) = calibrate(reg, 0);
    let (light_rate, _, _) = calibrate(reg, 2);
    let heavy_per_s = frac * hosts as f64 * heavy_rate;
    let horizon_s = n_heavy as f64 / heavy_per_s;
    let light_per_s = 0.25 * hosts as f64 * light_rate;
    let n_light = ((light_per_s * horizon_s) as usize).max(150);
    vec![
        Tenant {
            name: "heavy-be".into(),
            model: "heavy".into(),
            class: 2,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: heavy_per_s,
                n: n_heavy,
            },
        },
        Tenant {
            name: "light-int".into(),
            model: "light".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: light_per_s,
                n: n_light,
            },
        },
    ]
}

/// All three models warm on every board: hedges, steals and failovers
/// always have an eligible destination.
fn all_on_all(nb: usize) -> Vec<Vec<usize>> {
    vec![vec![0, 1, 2]; nb]
}

/// A thermal gray-failure window on `board`: both lanes stretched by
/// `scale` across the middle of the run.  The board stays up and keeps
/// accepting work — exactly the failure mode a liveness check misses.
fn thermal_plan(board: usize, scale: f64, horizon_us: f64)
    -> FaultPlan
{
    FaultPlan {
        faults: vec![
            Fault::Thermal {
                board,
                proc: Proc::Gpu,
                at_us: 0.15 * horizon_us,
                until_us: 0.75 * horizon_us,
                scale,
            },
            Fault::Thermal {
                board,
                proc: Proc::Cpu,
                at_us: 0.15 * horizon_us,
                until_us: 0.75 * horizon_us,
                scale,
            },
        ],
    }
}

/// Short breaker timescales so open/probe/close cycles fit inside the
/// test horizons (defaults are sized for the demo workloads).
fn fast_params() -> TailParams {
    TailParams {
        open_cooldown_us: 8_000.0,
        probe_interval_us: 2_000.0,
        ..TailParams::default()
    }
}

const HEDGE_BREAKER: TailPolicy =
    TailPolicy { hedge: true, breaker: true };

fn check_conserved(snap: &FleetSnapshot, n_arrivals: usize) {
    assert_eq!(snap.aggregate.total_offered() as usize, n_arrivals,
               "fleet lost or duplicated requests at admission");
    assert_eq!(
        snap.aggregate.total_served()
            + snap.aggregate.total_shed()
            + snap.total_failed(),
        snap.aggregate.total_offered(),
        "conservation broken: served {} + shed {} + failed {} != \
         offered {}",
        snap.aggregate.total_served(),
        snap.aggregate.total_shed(),
        snap.total_failed(),
        snap.aggregate.total_offered()
    );
}

fn queue_waits(snap: &FleetSnapshot) -> u64 {
    snap.boards
        .iter()
        .map(|b| {
            b.trace_events
                .iter()
                .filter(|r| {
                    matches!(r.event, TraceEvent::QueueWait { .. })
                })
                .count() as u64
        })
        .sum()
}

#[test]
fn off_policy_is_byte_stable_and_leaks_no_tail_keys() {
    // `hedge=off breaker=off` must arm nothing: byte-identical to the
    // default path with and without a thermal plan, deterministic, and
    // no tail counters in its JSON.
    let reg = registry3();
    let classes = classes_tail(&reg);
    let tenants = tail_tenants(&reg, 3, 0.9, 200);
    let arrivals = merge_arrivals(&tenants, 17);
    let horizon = arrivals.last().unwrap().at_us;
    for plan in [FaultPlan::none(), thermal_plan(0, 2.5, horizon)] {
        let run = |tail: TailPolicy| {
            let opts = FleetOptions {
                tail,
                faults: plan.clone(),
                placement: all_on_all(3),
                ..FleetOptions::new(3, 3)
            };
            run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                .unwrap()
                .to_json_string()
        };
        let default_opts = FleetOptions {
            faults: plan.clone(),
            placement: all_on_all(3),
            ..FleetOptions::new(3, 3)
        };
        let baseline =
            run_fleet(&reg, &classes, &tenants, &arrivals,
                      &default_opts)
                .unwrap()
                .to_json_string();
        assert_eq!(baseline, run(TailPolicy::OFF),
                   "explicit OFF differs from the default path");
        assert_eq!(baseline, run(TailPolicy::OFF),
                   "OFF run is not deterministic");
        for key in ["suspects", "breaker_opens", "\"probes\"",
                    "\"hedges\"", "hedge_wins", "hedge_waste_us"] {
            assert!(!baseline.contains(key),
                    "tail counter {key} leaked into an OFF report");
        }
    }
}

#[test]
fn conservation_exact_across_thermal_plans_routers_and_tail() {
    #[derive(Debug)]
    struct Case {
        nb: usize,
        router: RouterPolicy,
        tail: TailPolicy,
        scale: f64,
        frac: f64,
        seed: u64,
    }
    let reg = registry3();
    let classes = classes_tail(&reg);
    let routers = [
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::CostAware,
    ];
    let tails = [
        TailPolicy::OFF,
        TailPolicy { hedge: true, breaker: false },
        TailPolicy { hedge: false, breaker: true },
        HEDGE_BREAKER,
    ];
    let mut hedging_runs = 0usize;
    let mut opening_runs = 0usize;
    prop::check(
        "tail-conservation",
        10,
        20_260_807,
        |rng| Case {
            nb: 2 + rng.below(3),
            router: routers[rng.below(3)],
            tail: tails[rng.below(4)],
            scale: rng.range(1.8, 3.2),
            frac: rng.range(0.7, 1.3),
            seed: rng.next_u64() % 10_000,
        },
        |c| {
            let tenants = tail_tenants(&reg, c.nb, c.frac, 140);
            let arrivals = merge_arrivals(&tenants, c.seed);
            let horizon = arrivals.last().unwrap().at_us;
            let opts = FleetOptions {
                router: c.router,
                tail: c.tail,
                tail_params: fast_params(),
                faults: thermal_plan(0, c.scale, horizon),
                placement: all_on_all(c.nb),
                ..FleetOptions::new(c.nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .map_err(|e| e.to_string())?;
            let n = arrivals.len() as u64;
            if snap.aggregate.total_offered() != n {
                return Err(format!(
                    "offered {} != arrivals {n}",
                    snap.aggregate.total_offered()
                ));
            }
            let settled = snap.aggregate.total_served()
                + snap.aggregate.total_shed()
                + snap.total_failed();
            if settled != n {
                return Err(format!(
                    "conservation broken: served {} + shed {} + \
                     failed {} = {settled} != {n}",
                    snap.aggregate.total_served(),
                    snap.aggregate.total_shed(),
                    snap.total_failed()
                ));
            }
            // Policy gating: counters only move when armed.
            if !c.tail.hedge
                && (snap.total_hedges() != 0
                    || snap.total_hedge_wins() != 0
                    || snap.total_hedge_waste_us() != 0.0)
            {
                return Err("hedge counters moved with hedge off"
                    .into());
            }
            if !c.tail.breaker
                && (snap.total_breaker_opens() != 0
                    || snap.total_probes() != 0)
            {
                return Err("breaker counters moved with breaker off"
                    .into());
            }
            if !c.tail.enabled() && snap.total_suspects() != 0 {
                return Err("detector ran with tail off".into());
            }
            if snap.total_hedges() > 0 {
                hedging_runs += 1;
            }
            if snap.total_breaker_opens() > 0 {
                opening_runs += 1;
            }
            Ok(())
        },
    );
    assert!(hedging_runs > 0,
            "no randomized case ever hedged — the suite is vacuous");
    assert!(opening_runs > 0,
            "no randomized case ever opened a breaker — vacuous");
}

#[test]
fn hedge_racing_crash_settles_exactly_once() {
    // A thermally-stretched board breeds hedges; crashing it mid-run
    // kills queued and in-flight copies (some with a live twin) while
    // the fleet keeps reconciling.  Every request must settle exactly
    // once and conservation must stay exact.
    let reg = registry3();
    let classes = classes_tail(&reg);
    let nb = 3;
    let tenants = tail_tenants(&reg, nb, 1.0, 300);
    let arrivals = merge_arrivals(&tenants, 13);
    let horizon = arrivals.last().unwrap().at_us;
    let mut plan = thermal_plan(0, 2.8, horizon);
    plan.faults.push(Fault::Crash {
        board: 0,
        at_us: 0.45 * horizon,
        rejoin_us: Some(0.8 * horizon),
    });
    let opts = FleetOptions {
        tail: HEDGE_BREAKER,
        tail_params: fast_params(),
        faults: plan,
        placement: all_on_all(nb),
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert!(snap.total_hedges() > 0,
            "no hedge fired — the race never happened");
    assert_eq!(snap.total_failovers(), 1);
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0,
                   "board {i} dropped trace records");
    }
    assert_eq!(queue_waits(&snap), snap.aggregate.total_served(),
               "a request was served zero or multiple times");
}

#[test]
fn hedge_racing_preemption_settles_exactly_once() {
    // Hedged copies and deadline-burn preemption touch the same
    // in-flight ledger: a preempted batch may carry a hedge copy whose
    // twin settles in the same step.  Exactly-once must survive the
    // combination (plus stealing, which must never move a hedge-marked
    // copy between boards).
    let reg = registry3();
    let classes = classes_tail(&reg);
    let nb = 3;
    let tenants = tail_tenants(&reg, nb, 1.4, 350);
    let arrivals = merge_arrivals(&tenants, 23);
    let horizon = arrivals.last().unwrap().at_us;
    let opts = FleetOptions {
        tail: HEDGE_BREAKER,
        tail_params: fast_params(),
        preempt: PreemptionPolicy::BurnPlusSteal,
        faults: thermal_plan(1, 2.8, horizon),
        placement: all_on_all(nb),
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert!(snap.total_hedges() > 0,
            "no hedge fired alongside preemption");
    assert!(snap.total_preemptions() > 0,
            "no preemption fired alongside hedging");
    for (i, b) in snap.boards.iter().enumerate() {
        assert_eq!(b.trace_dropped, 0,
                   "board {i} dropped trace records");
    }
    assert_eq!(queue_waits(&snap), snap.aggregate.total_served(),
               "a request was served zero or multiple times");
}

#[test]
fn breaker_open_board_admits_only_probes_until_close() {
    // Once board 0's breaker opens, the only admissions it may see
    // until the breaker closes are probe dispatches: every Admit
    // record inside the open window must share a timestamp with a
    // Probe record (the probe is consumed at routing, immediately
    // before the offer, in the same virtual instant).
    let reg = registry3();
    let classes = classes_tail(&reg);
    let nb = 3;
    let tenants = tail_tenants(&reg, nb, 0.9, 300);
    let arrivals = merge_arrivals(&tenants, 41);
    let horizon = arrivals.last().unwrap().at_us;
    let opts = FleetOptions {
        tail: TailPolicy { hedge: false, breaker: true },
        tail_params: fast_params(),
        faults: thermal_plan(0, 3.0, horizon),
        placement: all_on_all(nb),
        trace: Some(TraceConfig::default()),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert!(snap.total_breaker_opens() > 0,
            "the stretched board never tripped its breaker");
    assert!(snap.total_probes() > 0,
            "an opened breaker never probed — probation untested");
    let b0 = &snap.boards[0];
    assert_eq!(b0.trace_dropped, 0, "board 0 dropped trace records");
    let t_open = b0
        .trace_events
        .iter()
        .find(|r| r.event == TraceEvent::BreakerOpen)
        .expect("board 0 opened but traced no BreakerOpen")
        .t_us;
    let t_close = b0
        .trace_events
        .iter()
        .find(|r| {
            r.event == TraceEvent::BreakerClose && r.t_us > t_open
        })
        .map_or(f64::INFINITY, |r| r.t_us);
    let probe_times: Vec<f64> = b0
        .trace_events
        .iter()
        .filter(|r| r.event == TraceEvent::Probe)
        .map(|r| r.t_us)
        .collect();
    let mut admits_in_window = 0usize;
    for r in &b0.trace_events {
        if r.event == TraceEvent::Admit
            && r.t_us > t_open
            && r.t_us < t_close
        {
            admits_in_window += 1;
            assert!(
                probe_times.iter().any(|&t| t == r.t_us),
                "non-probe admission at t={} inside the open window \
                 ({t_open}..{t_close})",
                r.t_us
            );
        }
    }
    // The window itself must not be vacuously empty of traffic: the
    // probes counter already proves probe admissions were attempted.
    let _ = admits_in_window;
}

#[test]
fn energy_ledger_reconciles_after_hedge_cancels() {
    // First-wins cancellation retracts the losing in-flight copy:
    // BoardPower::retract must refund the cancelled tail from both the
    // ledger and the busy-interval trace so they still agree exactly.
    let reg = registry3();
    let classes = classes_tail(&reg);
    let nb = 3;
    let tenants = tail_tenants(&reg, nb, 1.2, 300);
    let arrivals = merge_arrivals(&tenants, 29);
    let horizon = arrivals.last().unwrap().at_us;
    let profile =
        PowerProfile::from_device(&device_profile("agx_orin")).unwrap();
    let mut pc = PowerConfig::new(profile, Governor::RaceToIdle);
    pc.trace = true;
    let opts = FleetOptions {
        tail: HEDGE_BREAKER,
        tail_params: fast_params(),
        faults: thermal_plan(0, 2.8, horizon),
        placement: all_on_all(nb),
        power: Some(pc),
        ..FleetOptions::new(nb, 3)
    };
    let snap =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    check_conserved(&snap, arrivals.len());
    assert!(snap.total_hedges() > 0,
            "no hedge fired — the cancel/refund path went unexercised");
    for (i, board) in snap.boards.iter().enumerate() {
        assert_eq!(board.power_trace_dropped, 0,
                   "board {i} dropped busy intervals — raise trace_cap");
        let busy_mj: f64 = board
            .power_trace
            .iter()
            .map(|e| e.busy_w * (e.finish_us - e.start_us))
            .sum::<f64>()
            / 1e3;
        if busy_mj > 0.0 {
            let rel = (board.busy_energy_mj - busy_mj).abs()
                / busy_mj.abs().max(1e-12);
            assert!(rel < 1e-6,
                    "board {i} busy ledger {} != trace {busy_mj}",
                    board.busy_energy_mj);
        }
        let over_floor: f64 = board
            .power_trace
            .iter()
            .map(|e| (e.busy_w - e.idle_w) * (e.finish_us - e.start_us))
            .sum();
        let integral = (over_floor
            + (board.idle_floor_w + board.soc_w)
                * board.power_horizon_us)
            / 1e3;
        let denom =
            board.energy_mj.abs().max(integral.abs()).max(1e-12);
        assert!(
            ((board.energy_mj - integral) / denom).abs() < 1e-6,
            "board {i} energy {} != integral {integral}",
            board.energy_mj
        );
    }
}

#[test]
fn hedging_beats_control_on_interactive_attainment() {
    // The acceptance scenario: under a crash-free thermal gray-failure
    // plan, breaker+hedge must strictly beat the no-tail control on
    // interactive deadline attainment, summed across 3 seeds.
    let reg = registry3();
    let classes = classes_tail(&reg);
    let nb = 4;
    let mut met = std::collections::HashMap::new();
    let mut hedges = 0u64;
    for tail in [TailPolicy::OFF, HEDGE_BREAKER] {
        let mut m = 0u64;
        for seed in [3u64, 7u64, 11u64] {
            let tenants = tail_tenants(&reg, nb, 1.0, 400);
            let arrivals = merge_arrivals(&tenants, seed);
            let horizon = arrivals.last().unwrap().at_us;
            let opts = FleetOptions {
                tail,
                tail_params: fast_params(),
                faults: thermal_plan(0, 2.8, horizon),
                placement: all_on_all(nb),
                ..FleetOptions::new(nb, 3)
            };
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .unwrap();
            check_conserved(&snap, arrivals.len());
            if tail.enabled() {
                hedges += snap.total_hedges();
            }
            m += snap.aggregate.per_class[0].met;
        }
        met.insert(tail.name(), m);
    }
    assert!(hedges > 0, "hedging never fired across 3 seeds");
    assert!(
        met["hedge+breaker"] > met["off"],
        "hedge+breaker interactive met {} <= control {}",
        met["hedge+breaker"], met["off"]
    );
}
