//! Fast-path parity: the `engine::costs` entry points (`simulate` the
//! wrapper, `CostTable::simulate_into` + `SimScratch` reuse, and
//! `IncrementalSim::eval_flip`/`apply_flip`) must produce makespans —
//! and every other aggregate — exactly equal to the reference simulator
//! across randomized graphs, schedules, batches, noise settings and
//! sequences of placement flips.  Always-on (synthetic graphs + the
//! checked-in device profiles; no artifacts needed).

use sparoa::bench_support::{device_profile, prop};
use sparoa::engine::costs::{CostTable, SimScratch};
use sparoa::engine::sim::{
    simulate, simulate_reference, SimOptions, SimReport,
};
use sparoa::graph::ModelGraph;
use sparoa::scheduler::Schedule;
use sparoa::util::rng::Rng;

#[derive(Debug)]
struct Case {
    blocks: usize,
    scale: f64,
    sparsity: f64,
    batch: usize,
    noise: f64,
    seed: u64,
    device: &'static str,
    xi: Vec<f64>,
    flips: Vec<(usize, f64)>,
}

fn gen_case(r: &mut Rng) -> Case {
    let blocks = 1 + r.below(8);
    let n_ops = 1 + 3 * blocks + 2; // synthetic() chain length
    // Raw uniform xi hits CPU, GPU and the co-run band.
    let xi: Vec<f64> = (0..n_ops).map(|_| r.f64()).collect();
    let flips: Vec<(usize, f64)> = (0..1 + r.below(8))
        .map(|_| (r.below(n_ops), r.f64()))
        .collect();
    Case {
        blocks,
        scale: r.range(0.05, 5.0),
        sparsity: r.f64(),
        batch: 1 + r.below(8),
        noise: if r.below(2) == 0 { 0.0 } else { 0.05 },
        seed: r.below(1000) as u64,
        device: if r.below(2) == 0 { "agx_orin" } else { "orin_nano" },
        xi,
        flips,
    }
}

fn diff_aggregates(a: &SimReport, b: &SimReport) -> Result<(), String> {
    let pairs = [
        ("makespan_us", a.makespan_us, b.makespan_us),
        ("cpu_busy_us", a.cpu_busy_us, b.cpu_busy_us),
        ("gpu_busy_us", a.gpu_busy_us, b.gpu_busy_us),
        ("transfer_us", a.transfer_us, b.transfer_us),
        ("launch_us", a.launch_us, b.launch_us),
        ("aggregation_us", a.aggregation_us, b.aggregation_us),
        ("peak_gpu_mem_mb", a.peak_gpu_mem_mb, b.peak_gpu_mem_mb),
        ("cpu_mem_mb", a.cpu_mem_mb, b.cpu_mem_mb),
    ];
    for (name, x, y) in pairs {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name} differs: {x:?} vs {y:?}"));
        }
    }
    if a.switches != b.switches {
        return Err(format!(
            "switches differ: {} vs {}", a.switches, b.switches));
    }
    Ok(())
}

#[test]
fn fastpath_bitwise_equals_reference_under_random_cases() {
    prop::check("sim-fastpath-parity", 50, 0xC057AB1E, gen_case, |case| {
        let g = ModelGraph::synthetic(
            "parity", case.blocks, case.scale, case.sparsity);
        let dev = device_profile(case.device);
        let opts = SimOptions {
            batch: case.batch,
            noise: case.noise,
            seed: case.seed,
            ..Default::default()
        };
        let sched = Schedule { xi: case.xi.clone(), policy: "p".into() };
        let reference = simulate_reference(&g, &dev, &sched, &opts);

        // 1. The public `simulate` wrapper (fast walk, timings on).
        let fast = simulate(&g, &dev, &sched, &opts);
        diff_aggregates(&reference, &fast).map_err(|e| format!("wrapper: {e}"))?;
        if fast.timings.len() != reference.timings.len() {
            return Err(format!(
                "wrapper timings {} vs reference {}",
                fast.timings.len(),
                reference.timings.len()
            ));
        }
        for (a, b) in reference.timings.iter().zip(&fast.timings) {
            if a.op != b.op
                || a.proc != b.proc
                || a.start_us.to_bits() != b.start_us.to_bits()
                || a.finish_us.to_bits() != b.finish_us.to_bits()
                || a.compute_us.to_bits() != b.compute_us.to_bits()
                || a.transfer_us.to_bits() != b.transfer_us.to_bits()
            {
                return Err(format!("timing for op {} differs", a.op));
            }
        }

        // 2. Scratch reuse with record_timings off: aggregates still
        //    bit-identical, timing vec skipped, no state leak across
        //    repeated simulations into one scratch.
        let fast_opts =
            SimOptions { record_timings: false, ..opts.clone() };
        let table = CostTable::build(&g, &dev, &fast_opts);
        let mut scratch = SimScratch::new();
        for round in 0..2 {
            table.simulate_into(&sched, &mut scratch);
            diff_aggregates(&reference, &scratch.report)
                .map_err(|e| format!("scratch round {round}: {e}"))?;
            if !scratch.report.timings.is_empty() {
                return Err("record_timings=false recorded timings".into());
            }
        }

        // 3. Incremental evaluator: construction matches, tentative
        //    flips do not mutate, commits match a from-scratch reference
        //    simulation of the flipped schedule.
        let mut inc = table.incremental(&sched.xi);
        if inc.makespan_us().to_bits() != reference.makespan_us.to_bits() {
            return Err(format!(
                "incremental base {} vs reference {}",
                inc.makespan_us(),
                reference.makespan_us
            ));
        }
        let mut xi = case.xi.clone();
        for &(op, v) in &case.flips {
            let before = inc.makespan_us();
            let probe1 = inc.eval_flip(op, v);
            let probe2 = inc.eval_flip(op, v);
            if probe1.to_bits() != probe2.to_bits() {
                return Err("eval_flip is not deterministic".into());
            }
            if inc.makespan_us().to_bits() != before.to_bits() {
                return Err("eval_flip mutated committed state".into());
            }
            let committed = inc.apply_flip(op, v);
            if committed.to_bits() != probe1.to_bits() {
                return Err(format!(
                    "apply_flip {} disagrees with eval_flip {}",
                    committed, probe1
                ));
            }
            xi[op] = v;
            let flipped =
                Schedule { xi: xi.clone(), policy: "p".into() };
            let r2 = simulate_reference(&g, &dev, &flipped, &opts);
            if committed.to_bits() != r2.makespan_us.to_bits() {
                return Err(format!(
                    "flip (op {op} -> {v}): incremental {} vs \
                     reference {}",
                    committed, r2.makespan_us
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn wrapper_and_reference_agree_on_the_trivial_graph() {
    // Smallest end-to-end check: one block, batch 1, defaults.
    let g = ModelGraph::synthetic("tiny", 1, 1.0, 0.0);
    let dev = device_profile("agx_orin");
    let opts = SimOptions::default();
    for xi_val in [0.0, 0.5, 1.0] {
        let sched = Schedule::uniform(&g, xi_val, "u");
        let a = simulate_reference(&g, &dev, &sched, &opts);
        let b = simulate(&g, &dev, &sched, &opts);
        assert_eq!(a.makespan_us, b.makespan_us, "xi={xi_val}");
        assert_eq!(a.transfer_us, b.transfer_us, "xi={xi_val}");
        assert_eq!(a.switches, b.switches, "xi={xi_val}");
    }
}
