//! Table 3 integration: the AOT Transformer-LSTM predictor behind PJRT
//! beats the LR and CNN baselines on the held-out threshold dataset, and
//! its accuracy matches what the python training loop recorded.

use sparoa::predictor::{
    accuracy, LinearPredictor, PredictorDataset, ThresholdPredictor,
    N_FEATURES, SEQ_LEN,
};
use sparoa::runtime::{HostTensor, Runtime};

fn setup() -> Option<(PredictorDataset, Runtime)> {
    if !cfg!(feature = "pjrt") {
        eprintln!("built without the `pjrt` feature; skipping");
        return None;
    }
    let art = sparoa::artifacts_dir();
    if !art.join("predictor/dataset.json").exists() {
        eprintln!("predictor artifacts missing; skipping");
        return None;
    }
    Some((
        PredictorDataset::load(&art).unwrap(),
        Runtime::new(&art).unwrap(),
    ))
}

fn eval_hlo(rt: &Runtime, artifact: &str, ds: &PredictorDataset)
    -> (f64, f64)
{
    let pred = ThresholdPredictor::with_artifact(rt, artifact);
    let mut s_acc = 0.0;
    let mut c_acc = 0.0;
    let mut n = 0.0;
    for (x, y, m) in &ds.sequences {
        let rows: Vec<[f32; N_FEATURES]> = (0..SEQ_LEN)
            .map(|i| {
                let mut r = [0f32; N_FEATURES];
                r.copy_from_slice(&x[i * N_FEATURES..(i + 1) * N_FEATURES]);
                r
            })
            .collect();
        let p = pred.predict_window(&rows).unwrap();
        let (s, c) = accuracy(&p, y, m, 0.1);
        let w = m.iter().sum::<f32>() as f64;
        s_acc += s * w;
        c_acc += c * w;
        n += w;
    }
    (s_acc / n, c_acc / n)
}

#[test]
fn transformer_lstm_beats_baselines_on_test_set() {
    let Some((ds, rt)) = setup() else { return };
    let (ours_s, ours_c) =
        eval_hlo(&rt, "predictor/thresh_predictor.hlo.txt", &ds);
    let (cnn_s, cnn_c) = eval_hlo(&rt, "predictor/cnn_predictor.hlo.txt", &ds);

    // LR natively.
    let mut lr_s = 0.0;
    let mut lr_c = 0.0;
    let mut n = 0.0;
    for (x, y, m) in &ds.sequences {
        let preds: Vec<(f64, f64)> = (0..SEQ_LEN)
            .map(|i| {
                let mut r = [0f32; N_FEATURES];
                r.copy_from_slice(&x[i * N_FEATURES..(i + 1) * N_FEATURES]);
                ds.lr.predict(&r)
            })
            .collect();
        let (s, c) = accuracy(&preds, y, m, 0.1);
        let w = m.iter().sum::<f32>() as f64;
        lr_s += s * w;
        lr_c += c * w;
        n += w;
    }
    lr_s /= n;
    lr_c /= n;

    println!("Table 3: ours=({ours_s:.3},{ours_c:.3}) \
              cnn=({cnn_s:.3},{cnn_c:.3}) lr=({lr_s:.3},{lr_c:.3})");
    assert!(ours_s > cnn_s && cnn_s > lr_s,
            "sparsity ordering: {ours_s} / {cnn_s} / {lr_s}");
    assert!(ours_c > lr_c, "intensity: ours {ours_c} vs lr {lr_c}");
    assert!(ours_s > 0.85, "ours sparsity accuracy {ours_s}");
    assert!(ours_c > 0.75, "ours intensity accuracy {ours_c}");
}

#[test]
fn hlo_accuracy_matches_training_record() {
    let Some((ds, rt)) = setup() else { return };
    let (ours_s, ours_c) =
        eval_hlo(&rt, "predictor/thresh_predictor.hlo.txt", &ds);
    let rec = ds
        .trained_accuracy
        .iter()
        .find(|(k, _, _)| k == "ours")
        .unwrap();
    assert!((ours_s - rec.1).abs() < 0.02,
            "rust-side {ours_s} vs python-side {}", rec.1);
    assert!((ours_c - rec.2).abs() < 0.02,
            "rust-side {ours_c} vs python-side {}", rec.2);
}

#[test]
fn predictions_stay_in_unit_interval() {
    let Some((_, rt)) = setup() else { return };
    let pred = ThresholdPredictor::new(&rt);
    let rows: Vec<[f32; N_FEATURES]> = (0..SEQ_LEN)
        .map(|i| {
            let f = i as f32 / SEQ_LEN as f32;
            [f, 1.0 - f, 0.5, 2.0 * f, f, 1.0]
        })
        .collect();
    for (s, c) in pred.predict_window(&rows).unwrap() {
        assert!((0.0..=1.0).contains(&s) && (0.0..=1.0).contains(&c));
    }
}

#[test]
fn linear_predictor_loads_sane_weights() {
    let Some((ds, _)) = setup() else { return };
    let LinearPredictor { w } = ds.lr;
    for row in &w {
        for v in row {
            assert!(v.is_finite());
        }
    }
}
