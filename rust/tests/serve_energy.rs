//! Energy-aware serving invariants — always-on (synthetic models +
//! checked-in device profiles; no `make artifacts` gating).
//!
//! * conservation: each board's reported energy equals the integral of
//!   its power timeline reconstructed from the busy-interval trace
//!   (busy intervals at the chosen rung's draw, idle gaps at the lane
//!   floor, SoC floor over the whole horizon) to within 1e-6 relative;
//! * governor ordering: under light load StretchToDeadline spends
//!   strictly fewer joules per inference than RaceToIdle while giving
//!   up at most the noise floor (0.5 pp) of SLO attainment;
//! * power cap: with a cap installed, the reconstructed instantaneous
//!   board draw never exceeds it at any busy-interval boundary, and the
//!   binding cap surfaces as throttle events;
//! * an infeasible cap (too tight to ever dispatch) is rejected up
//!   front by `run_fleet` instead of stalling the virtual clock.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{device_profile, prop};
use sparoa::device::Proc;
use sparoa::graph::ModelGraph;
use sparoa::power::{Governor, PowerConfig, PowerProfile};
use sparoa::serve::{
    merge_arrivals, run_fleet, ArrivalPattern, AutoscalePolicy,
    EnergySlo, FleetOptions, FleetSnapshot, ModelRegistry, PerfSnapshot,
    RouterPolicy, SloClass, Tenant,
};

/// heavy = 0, mid = 1, light = 2 (the demo fleet's synthetic shapes).
fn registry3() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in [
        ("heavy", 8, 6.0, 0.1),
        ("mid", 6, 1.5, 0.45),
        ("light", 4, 0.3, 0.75),
    ] {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(
                name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

/// Max req/s of one replica's best lane at the full Alg. 2 batch.
fn rate_of(reg: &ModelRegistry, m: usize) -> f64 {
    let e = reg.get(m);
    let cap = e.gpu_batch_cap.max(1);
    let gpu_rate =
        cap as f64 / e.latency_us(Proc::Gpu, cap).unwrap() * 1e6;
    let ccap = e.cpu_batch_cap.max(1);
    let cpu_rate =
        ccap as f64 / e.latency_us(Proc::Cpu, ccap).unwrap() * 1e6;
    gpu_rate.max(cpu_rate)
}

/// Interactive / standard / best-effort classes scaled to the heavy
/// model's real costs (same shape as `serve_fleet.rs`).
fn classes_for(reg: &ModelRegistry) -> Vec<SloClass> {
    let heavy = reg.get(0);
    let heavy_batch = heavy
        .latency_us(Proc::Gpu, heavy.gpu_batch_cap.max(1))
        .unwrap();
    let heavy_lat1 = heavy.cheapest_latency_us(1).unwrap();
    let mid_lat1 = reg.get(1).cheapest_latency_us(1).unwrap();
    let interactive = (1.2 * heavy_batch).max(4.0 * mid_lat1);
    let standard = (3.5 * heavy_batch).max(3.0 * heavy_lat1);
    vec![
        SloClass::new("interactive", interactive, 128, 4.0),
        SloClass::new("standard", standard, 256, 2.0),
        SloClass::new("best-effort", 15.0 * heavy_batch, 512, 1.0),
    ]
}

/// The demo three-tenant mix at a given load multiplier.
fn tenants_at(reg: &ModelRegistry, load: f64, n: usize) -> Vec<Tenant> {
    let heavy_rate = rate_of(reg, 0);
    let mid_rate = rate_of(reg, 1);
    vec![
        Tenant {
            name: "heavy-std".into(),
            model: "heavy".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: load * heavy_rate,
                n,
            },
        },
        Tenant {
            name: "mid-inter".into(),
            model: "mid".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 0.3 * load * mid_rate,
                n,
            },
        },
        Tenant {
            name: "light-be".into(),
            model: "light".into(),
            class: 2,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: load * heavy_rate,
                n: n / 2,
            },
        },
    ]
}

fn traced_config(governor: Governor) -> PowerConfig {
    let profile =
        PowerProfile::from_device(&device_profile("agx_orin")).unwrap();
    let mut pc = PowerConfig::new(profile, governor);
    pc.trace = true;
    pc
}

/// Integrate one board's power timeline from its busy-interval trace:
/// busy intervals add (busy_w - idle_w) over the floor; the floor
/// (lane idle draws + SoC) accrues over the whole horizon.  Returns mJ.
fn integrate_board(snap: &PerfSnapshot) -> f64 {
    let over_floor: f64 = snap
        .power_trace
        .iter()
        .map(|e| (e.busy_w - e.idle_w) * (e.finish_us - e.start_us))
        .sum();
    (over_floor + (snap.idle_floor_w + snap.soc_w)
        * snap.power_horizon_us)
        / 1e3
}

fn assert_close(a: f64, b: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1e-12);
    assert!(
        ((a - b) / denom).abs() < 1e-6,
        "{what}: {a} vs {b} (relative error {})",
        ((a - b) / denom).abs()
    );
}

#[test]
fn reported_energy_matches_power_timeline_integral() {
    let reg = registry3();
    let classes = classes_for(&reg);
    let governors = [
        Governor::RaceToIdle,
        Governor::StretchToDeadline,
        Governor::FixedState(2),
    ];
    prop::check(
        "energy-conservation",
        6,
        1177,
        |rng| {
            let nb = 1 + rng.below(3);
            let gov = governors[rng.below(3)];
            let load = rng.range(0.2, 1.5);
            let autoscale = rng.below(2) == 1;
            let seed = rng.next_u64() % 10_000;
            (nb, gov, load, autoscale, seed)
        },
        |&(nb, gov, load, autoscale, seed)| {
            let tenants = tenants_at(&reg, load, 150);
            let arrivals = merge_arrivals(&tenants, seed);
            let mut opts = FleetOptions::new(nb, 3);
            opts.power = Some(traced_config(gov));
            if autoscale {
                // Warmup charges are busy intervals too: the ledger
                // must balance with scale-up warmups in the timeline.
                opts.autoscale = Some(AutoscalePolicy::default());
            }
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .map_err(|e| e.to_string())?;
            if snap.governor != gov.name() {
                return Err(format!(
                    "fleet governor `{}` != `{}`",
                    snap.governor,
                    gov.name()
                ));
            }
            for (b, board) in snap.boards.iter().enumerate() {
                // Ledger vs trace: busy-interval energy sums exactly.
                let busy_mj: f64 = board
                    .power_trace
                    .iter()
                    .map(|e| e.busy_w * (e.finish_us - e.start_us))
                    .sum::<f64>()
                    / 1e3;
                let rel = (board.busy_energy_mj - busy_mj).abs()
                    / busy_mj.abs().max(1e-12);
                if busy_mj > 0.0 && rel > 1e-6 {
                    return Err(format!(
                        "board {b} busy ledger {} != trace {busy_mj}",
                        board.busy_energy_mj
                    ));
                }
                // Total vs the full power-timeline integral.
                let integral = integrate_board(board);
                let denom =
                    board.energy_mj.abs().max(integral.abs()).max(1e-12);
                if ((board.energy_mj - integral) / denom).abs() > 1e-6 {
                    return Err(format!(
                        "board {b} energy {} != integral {integral}",
                        board.energy_mj
                    ));
                }
                // Horizon covers every traced interval and the
                // latency makespan.
                let last = board
                    .power_trace
                    .iter()
                    .map(|e| e.finish_us)
                    .fold(0.0, f64::max);
                if board.power_horizon_us + 1e-9 < last
                    || board.power_horizon_us + 1e-9
                        < board.makespan_us
                {
                    return Err(format!(
                        "board {b} horizon {} < busy tail {last} or \
                         makespan {}",
                        board.power_horizon_us, board.makespan_us
                    ));
                }
            }
            // The fleet aggregate is the sum of the boards.
            let sum: f64 =
                snap.boards.iter().map(|b| b.energy_mj).sum();
            let denom = sum.abs().max(1e-12);
            if ((snap.aggregate.energy_mj - sum) / denom).abs() > 1e-9 {
                return Err(format!(
                    "aggregate energy {} != board sum {sum}",
                    snap.aggregate.energy_mj
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn stretch_governor_saves_energy_at_light_load() {
    // Light load leaves slack on every deadline, so stretch-to-deadline
    // runs slower rungs: strictly fewer joules per inference, at most a
    // noise-floor attainment give-up vs race-to-idle.
    let reg = registry3();
    let classes = classes_for(&reg);
    let run = |gov: Governor| -> (f64, f64, f64) {
        let mut served = 0u64;
        let mut met = 0u64;
        let mut energy = 0.0;
        for seed in [3u64, 7, 11] {
            let tenants = tenants_at(&reg, 0.35, 250);
            let arrivals = merge_arrivals(&tenants, seed);
            let mut opts = FleetOptions::new(2, 3);
            opts.router = RouterPolicy::CostAware;
            opts.power = Some(traced_config(gov));
            let snap =
                run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
                    .unwrap();
            served += snap.aggregate.total_served();
            met += snap.aggregate.total_met();
            energy += snap.aggregate.energy_mj;
        }
        assert!(served > 0, "light-load run served nothing");
        (
            met as f64 / served as f64,
            energy / served as f64,
            energy,
        )
    };
    let (race_attain, race_mj_inf, _) = run(Governor::RaceToIdle);
    let (stretch_attain, stretch_mj_inf, _) =
        run(Governor::StretchToDeadline);
    assert!(
        stretch_mj_inf < race_mj_inf,
        "stretch {stretch_mj_inf} mJ/inf >= race {race_mj_inf} mJ/inf"
    );
    assert!(
        stretch_attain >= race_attain - 0.005,
        "stretch attainment {stretch_attain} fell more than the noise \
         floor below race {race_attain}"
    );
    // The energy SLO vocabulary judges the same numbers: a budget
    // between the two governors separates them.
    let budget = EnergySlo::new((stretch_mj_inf + race_mj_inf) / 2.0);
    assert!(budget.met(stretch_mj_inf));
    assert!(!budget.met(race_mj_inf));
}

/// Instantaneous draw at time `t` reconstructed from a board's trace.
fn draw_at(snap: &PerfSnapshot, t: f64) -> f64 {
    let over_floor: f64 = snap
        .power_trace
        .iter()
        .filter(|e| e.start_us <= t && t < e.finish_us)
        .map(|e| e.busy_w - e.idle_w)
        .sum();
    snap.soc_w + snap.idle_floor_w + over_floor
}

#[test]
fn power_cap_is_never_exceeded_and_surfaces_throttles() {
    let reg = registry3();
    let classes = classes_for(&reg);
    let profile =
        PowerProfile::from_device(&device_profile("agx_orin")).unwrap();
    // Cap fits {gpu mid rung + idle cpu} but not the gpu max rung:
    // race-to-idle's picks get clamped (and concurrent cpu work
    // deferred), so the cap is binding throughout the run.
    let cap = profile.soc_static_w
        + profile.cpu.idle_w
        + profile.gpu.states[1].busy_power_w()
        + 0.01;
    let mut pc = traced_config(Governor::RaceToIdle);
    pc.cap_w = Some(cap);
    let tenants = tenants_at(&reg, 0.8, 220);
    let arrivals = merge_arrivals(&tenants, 17);
    let mut opts = FleetOptions::new(2, 3);
    opts.power = Some(pc);
    let snap: FleetSnapshot =
        run_fleet(&reg, &classes, &tenants, &arrivals, &opts).unwrap();
    assert!(
        snap.total_throttles() >= 1,
        "a binding cap must surface throttle events"
    );
    // Board draw only steps up at busy-interval starts, so checking
    // every start (plus just-inside every finish) bounds all instants.
    for (b, board) in snap.boards.iter().enumerate() {
        assert!(!board.power_trace.is_empty(),
                "board {b} dispatched nothing");
        for e in &board.power_trace {
            for t in [e.start_us, e.finish_us - 1e-9] {
                let w = draw_at(board, t);
                assert!(
                    w <= cap + 1e-9,
                    "board {b} draws {w} W > cap {cap} W at t={t}"
                );
            }
        }
        // Conservation holds under the cap too.
        assert_close(
            board.energy_mj,
            integrate_board(board),
            "capped-board energy",
        );
    }
}

#[test]
fn infeasible_cap_is_rejected_by_run_fleet() {
    let reg = registry3();
    let classes = classes_for(&reg);
    let tenants = tenants_at(&reg, 0.3, 40);
    let arrivals = merge_arrivals(&tenants, 1);
    let mut pc = traced_config(Governor::RaceToIdle);
    pc.cap_w = Some(0.5); // below the all-idle floor + slowest rung
    let mut opts = FleetOptions::new(2, 3);
    opts.power = Some(pc);
    let err = run_fleet(&reg, &classes, &tenants, &arrivals, &opts)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("infeasible"),
        "unhelpful error: {err:#}"
    );
}
