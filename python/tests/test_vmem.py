"""L1 structural performance tests: every kernel configuration used by the
exec- AND paper-scale models fits the VMEM budget with double-buffering,
and the hot matmul path keeps MXU utilization high at paper scale."""
import pytest

from compile import model, vmem


def test_default_matmul_tile_fits_and_saturates_mxu():
    e = vmem.matmul_estimate(1024, 512, 1024)
    assert e.fits_vmem
    assert e.mxu_utilization == 1.0


def test_paper_scale_convs_fit_vmem():
    g = model.build("resnet18", "paper")
    for op in g.ops:
        if op.kind == "conv2d":
            n, h, w, cin = op.in_shapes[0]
            a = op.attrs
            e = vmem.conv_estimate(n, h, w, a["cin"], a["cout"], a["kh"],
                                   a["kw"], a["stride"], a["padding"])
            assert e.fits_vmem, f"{op.name}: {e.vmem_bytes} bytes"


def test_paper_scale_attention_fits_vmem():
    for name in ("vit_b16", "swin_t"):
        g = model.build(name, "paper")
        for op in g.ops:
            if op.kind == "attention":
                b, t, three_c = op.in_shapes[0]
                d = three_c // 3 // op.attrs["heads"]
                e = vmem.attention_estimate(t, d)
                assert e.fits_vmem, f"{name}:{op.name}"


def test_heavy_paper_matmuls_keep_mxu_busy():
    g = model.build("vit_b16", "paper")
    utils = []
    for op in g.ops:
        if op.kind == "linear" and op.flops > 1e8:
            rows = 1
            for s in op.in_shapes[0][:-1]:
                rows *= s
            e = vmem.matmul_estimate(rows, op.attrs["din"],
                                     op.attrs["dout"])
            utils.append(e.mxu_utilization)
    assert utils and min(utils) > 0.5, utils


def test_dwconv_lane_occupancy_reported():
    e = vmem.dwconv_estimate(56, 56, 96, 3, 3)
    assert e.fits_vmem
    assert 0.0 < e.mxu_utilization <= 1.0
