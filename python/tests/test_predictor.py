"""Threshold-predictor tests: ground-truth labelling, architecture shapes,
short-training sanity, baseline ordering (Table 3 at reduced scale)."""
import numpy as np
import pytest

from compile import device_model as dm
from compile import model, predictor


@pytest.fixture(scope="module")
def cfg():
    return dm.load()


def test_sparsity_threshold_monotone_semantics(cfg):
    dev = cfg["devices"]["agx_orin"]
    # Heavier dense conv => CPU needs more sparsity to compete => higher s*.
    light = dm.sparsity_threshold(dev, "conv", 1e6, 1e5, 1e4)
    heavy = dm.sparsity_threshold(dev, "conv", 1e9, 1e7, 1e6)
    assert 0.0 <= light <= 1.0 and 0.0 <= heavy <= 1.0
    assert heavy >= light


def test_norm_ops_prefer_cpu(cfg):
    dev = cfg["devices"]["agx_orin"]
    s = dm.sparsity_threshold(dev, "norm", 1e5, 4e5, 4e5)
    assert s == 0.0, "tiny norm op: CPU wins at any sparsity"


def test_intensity_threshold_in_range(cfg):
    dev = cfg["devices"]["orin_nano"]
    c = dm.intensity_threshold(dev, "matmul", 1e7, 1e6, 0.3, 1e5)
    assert 0.0 <= c <= 1.0


def test_norm_intensity_clamps():
    assert dm.norm_intensity(1.0) == 0.0
    assert dm.norm_intensity(1e20) == 1.0
    mid = dm.norm_intensity(10 ** 7.5)
    assert 0.0 < mid < 1.0


@pytest.fixture(scope="module")
def small_dataset():
    g = model.build("resnet18", "paper")
    sp = np.clip(np.random.default_rng(0).random(len(g.ops)), 0, 1)
    feats, labels, classes = predictor.build_dataset([(g, sp)], seed=1)
    return feats, labels


def test_dataset_shapes_and_ranges(small_dataset):
    feats, labels = small_dataset
    assert feats.shape[1] == predictor.N_FEATURES
    assert labels.shape[1] == 2
    assert np.all((labels >= 0) & (labels <= 1))
    assert np.all(np.isfinite(feats))


def test_sequence_packing_masks(small_dataset):
    feats, labels = small_dataset
    X, Y, M = predictor.to_sequences(feats, labels)
    assert X.shape[1] == predictor.SEQ_LEN
    assert int(M.sum()) == feats.shape[0]
    # padded tail rows must be zero
    last = int(M[-1].sum())
    assert np.all(X[-1, last:] == 0.0)


def test_forward_shapes_and_range(small_dataset):
    feats, labels = small_dataset
    X, _, _ = predictor.to_sequences(feats, labels)
    import jax
    p = predictor.init_params(jax.random.PRNGKey(0))
    out = np.asarray(predictor.forward(p, X[:2]))
    assert out.shape == (2, predictor.SEQ_LEN, 2)
    assert np.all((out > 0) & (out < 1)), "sigmoid head"


def test_short_training_reduces_loss(small_dataset):
    # Full Table-3 ordering is asserted against the real 2.5k-sample
    # dataset in test_aot.py; this is a fast learning-sanity check on a
    # single-model dataset (too small for a reliable ours-vs-LR gap).
    import jax
    feats, labels = small_dataset
    X, Y, M = predictor.to_sequences(feats, labels)
    p0 = predictor.init_params(jax.random.PRNGKey(0))
    loss0 = float(predictor.loss_fn(p0, X, Y, M))
    p = predictor.train(X, Y, M, epochs=40, log=lambda *_: None)
    loss1 = float(predictor.loss_fn(p, X, Y, M))
    assert loss1 < 0.5 * loss0, f"no learning: {loss0} -> {loss1}"


def test_model_size_matches_paper_scale():
    import jax
    p = predictor.init_params(jax.random.PRNGKey(0))
    mb = predictor.param_count(p) * 4 / 1e6
    assert 1.0 < mb < 8.0, f"predictor ~4MB per paper, got {mb:.1f}MB"
