"""L2 model-graph tests: structure, scale-zip invariants, FLOPs sanity,
interpreter execution."""
import math

import numpy as np
import pytest

from compile import datagen, interp, model
from compile.graph_ir import KIND_CLASS, KINDS, signature, zip_scales

PAPER_PARAMS_M = {
    # paper Table 2
    "resnet18": 11.7,
    "mobilenet_v2": 3.5,       # paper lists 2.5M for v2 / 3.5M for v3;
    "mobilenet_v3_small": 2.5,  # the table's two rows are widely agreed to
    "vit_b16": 86.0,            # be swapped (torchvision: v2=3.5M,
    "swin_t": 28.0,             # v3-small=2.5M)
}


@pytest.mark.parametrize("name", list(model.MODELS))
def test_scales_zip(name):
    ge = model.build(name, "exec")
    gp = model.build(name, "paper")
    zip_scales(ge, gp)
    assert ge.ops[0].kind == "input"
    for op in ge.ops:
        for i in op.inputs:
            assert i < op.id, "topological order violated"
        assert op.kind in KINDS
        assert op.kind in KIND_CLASS


@pytest.mark.parametrize("name,params_m", PAPER_PARAMS_M.items())
def test_paper_param_counts(name, params_m):
    gp = model.build(name, "paper")
    total = sum(sum(math.prod(s) for s in o.param_shapes) for o in gp.ops)
    assert abs(total / 1e6 - params_m) / params_m < 0.12, \
        f"{name}: {total/1e6:.1f}M params vs paper {params_m}M"


@pytest.mark.parametrize("name", list(model.MODELS))
def test_op_counts_in_paper_ballpark(name):
    # Table 2 lists 53-125 operators; our graphs count each primitive op.
    gp = model.build(name, "paper")
    assert 50 <= len(gp.ops) <= 200


def test_flops_scale_with_resolution():
    ge = model.build("resnet18", "exec")
    gp = model.build("resnet18", "paper")
    fe = sum(o.flops for o in ge.ops)
    fp = sum(o.flops for o in gp.ops)
    assert fp > 50 * fe


def test_signatures_unique_per_distinct_shape():
    g = model.build("mobilenet_v3_small", "exec")
    convs = [o for o in g.ops if o.kind == "conv2d"]
    sigs = {signature(o) for o in convs}
    shapes = {(tuple(o.in_shapes[0]), tuple(o.param_shapes[0]),
               tuple(sorted(o.attrs.items()))) for o in convs}
    assert len(sigs) == len(shapes)


@pytest.mark.parametrize("name", ["mobilenet_v2", "vit_b16"])
def test_interpreter_runs_and_measures_sparsity(name):
    g = model.build(name, "exec")
    params = datagen.init_params(g, seed=3)
    x = datagen.sample_input(g.input_shape, seed=0)
    out, sp = interp.run(g, params, x)
    assert tuple(out.shape) == g.ops[-1].out_shape
    assert np.all(np.isfinite(out))
    assert np.all((sp >= 0) & (sp <= 1))
    if name == "mobilenet_v2":  # relu6 produces exact zeros
        assert sp.max() > 0.3


def test_weight_flattening_roundtrip():
    g = model.build("resnet18", "exec")
    params = datagen.init_params(g, seed=1)
    buf, slices = datagen.flatten_params(params)
    for op in g.ops:
        for rec, p in zip(slices[op.id], params[op.id]):
            got = buf[rec["offset"]:rec["offset"] + rec["numel"]]
            np.testing.assert_array_equal(got, p.reshape(-1))
            assert rec["shape"] == list(p.shape)


def test_sparsity_knob_spreads_relu_outputs():
    g = model.build("resnet18", "exec")
    params = datagen.init_params(g, seed=5)
    sp = interp.measure_sparsity(g, params, n_inputs=1)
    relu_sp = [sp[o.id] for o in g.ops if o.kind == "relu"]
    assert max(relu_sp) - min(relu_sp) > 0.3, \
        "BN beta offsets should spread post-ReLU sparsity"
