"""AOT artifact tests: HLO text format, manifest integrity, topology JSON
schema (what the rust loader depends on)."""
import json
import pathlib

import pytest

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="run `make artifacts` first")


def test_manifest_lists_all_models():
    m = json.loads((ART / "manifest.json").read_text())
    assert set(m["models"]) == {
        "resnet18", "mobilenet_v2", "mobilenet_v3_small", "vit_b16",
        "swin_t"}
    assert m["n_op_artifacts"] > 100


@pytest.mark.parametrize("model", [
    "resnet18", "mobilenet_v2", "mobilenet_v3_small", "vit_b16", "swin_t"])
def test_topology_schema(model):
    t = json.loads((ART / "models" / model / "topology.json").read_text())
    assert t["model"] == model
    weights = (ART / "models" / model / t["weights_file"])
    assert weights.exists()
    total = 0
    for o in t["ops"]:
        for key in ("id", "name", "kind", "class", "inputs",
                    "exec_out_shape", "flops_paper", "sparsity_in",
                    "sparsity_out", "weights"):
            assert key in o, f"{model} op missing {key}"
        assert 0.0 <= o["sparsity_out"] <= 1.0
        for w in o["weights"]:
            total = max(total, w["offset"] + w["numel"])
        if o["kind"] not in ("input", "reshape"):
            assert o["artifact"], f"{model}:{o['name']} missing artifact"
            assert (ART / o["artifact"]).exists()
    assert total * 4 == weights.stat().st_size


def test_hlo_artifacts_are_text_modules():
    ops = list((ART / "ops").glob("*.hlo.txt"))
    assert len(ops) > 100
    for p in ops[:20]:
        head = p.read_text()[:200]
        assert "HloModule" in head, f"{p.name} is not HLO text"


def test_predictor_artifacts_present():
    assert (ART / "predictor" / "thresh_predictor.hlo.txt").exists()
    assert (ART / "predictor" / "cnn_predictor.hlo.txt").exists()
    ds = json.loads((ART / "predictor" / "dataset.json").read_text())
    acc = ds["accuracy"]
    # Table 3 ordering: ours >> cnn >> lr on both outputs.
    assert acc["ours"][0] > acc["cnn"][0] > acc["lr"][0]
    assert acc["ours"][0] > 0.85 and acc["ours"][1] > 0.75
    assert len(ds["lr_weights"]) == 2 and len(ds["lr_weights"][0]) == 7


def test_devices_json_copied():
    d = json.loads((ART / "devices.json").read_text())
    assert "agx_orin" in d["devices"] and "orin_nano" in d["devices"]
