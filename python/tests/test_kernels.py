"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes/sparsity with hypothesis.  This is the core correctness signal for
the compute layer."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import conv as conv_k
from compile.kernels import elementwise as ew_k
from compile.kernels import matmul as mm_k
from compile.kernels import norm as norm_k
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(mm_k.matmul(x, y), ref.matmul(x, y),
                               rtol=1e-4, atol=1e-4)


@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 40),
       sparsity=st.floats(0.0, 1.0), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_sparse_matmul_matches_dense_ref(m, k, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k) * (rng.random((m, k)) > sparsity)
    y = rand(rng, k, n)
    np.testing.assert_allclose(mm_k.sparse_matmul(x.astype(np.float32), y),
                               ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_sparse_matmul_all_zero_input():
    x = np.zeros((33, 17), np.float32)
    y = np.ones((17, 9), np.float32)
    out = np.asarray(mm_k.sparse_matmul(x, y))
    assert np.all(out == 0.0)


@given(b=st.integers(1, 2), hw=st.integers(4, 12), cin=st.integers(1, 6),
       cout=st.integers(1, 8), stride=st.sampled_from([1, 2]),
       k=st.sampled_from([1, 3]), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_conv2d_matches_ref(b, hw, cin, cout, stride, k, seed):
    rng = np.random.default_rng(seed)
    pad = k // 2
    x = rand(rng, b, hw, hw, cin)
    w = rand(rng, k, k, cin, cout)
    np.testing.assert_allclose(
        conv_k.conv2d(x, w, stride=stride, padding=pad),
        ref.conv2d(x, w, stride, pad), rtol=1e-3, atol=1e-3)


@given(hw=st.integers(4, 12), c=st.integers(1, 10),
       stride=st.sampled_from([1, 2]), k=st.sampled_from([3, 5]),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_depthwise_conv_matches_ref(hw, c, stride, k, seed):
    rng = np.random.default_rng(seed)
    pad = k // 2
    x = rand(rng, 1, hw, hw, c)
    w = rand(rng, k, k, c)
    np.testing.assert_allclose(
        conv_k.depthwise_conv2d(x, w, stride=stride, padding=pad),
        ref.depthwise_conv2d(x, w, stride, pad), rtol=1e-3, atol=1e-3)


@given(rows=st.integers(1, 200), d=st.integers(2, 96),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_layernorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = rand(rng, rows, d), rand(rng, d), rand(rng, d)
    np.testing.assert_allclose(norm_k.layernorm(x, g, b),
                               ref.layernorm(x, g, b), rtol=1e-3, atol=1e-3)


@given(rows=st.integers(1, 200), c=st.integers(1, 64),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_batchnorm_matches_ref(rows, c, seed):
    rng = np.random.default_rng(seed)
    x, g, b = rand(rng, rows, c), rand(rng, c), rand(rng, c)
    mean = rand(rng, c)
    var = (rng.random(c) + 0.05).astype(np.float32)
    np.testing.assert_allclose(norm_k.batchnorm(x, g, b, mean, var),
                               ref.batchnorm(x, g, b, mean, var),
                               rtol=1e-3, atol=1e-3)


@given(bh=st.integers(1, 8), t=st.integers(1, 24), d=st.integers(2, 24),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_attention_matches_ref(bh, t, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, bh, t, d), rand(rng, bh, t, d), rand(rng, bh, t, d)
    want = np.stack([ref.attention(q[i], k[i], v[i]) for i in range(bh)])
    np.testing.assert_allclose(attn_k.attention(q, k, v), want,
                               rtol=1e-3, atol=1e-3)


@given(rows=st.integers(1, 100), d=st.integers(1, 64),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_softmax_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, d) * 10.0
    out = np.asarray(attn_k.softmax(x))
    np.testing.assert_allclose(out, ref.softmax(x), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(out.sum(-1), 1.0, rtol=1e-4)


@pytest.mark.parametrize("name", ["relu", "relu6", "hardswish",
                                  "hardsigmoid", "gelu"])
@given(rows=st.integers(1, 120), d=st.integers(1, 80),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_elementwise_matches_ref(name, rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, d) * 4.0
    f = getattr(ew_k, name)
    rf = getattr(ref, name)
    np.testing.assert_allclose(f(x), rf(x), rtol=1e-4, atol=1e-5)


def test_relu_produces_expected_sparsity():
    rng = np.random.default_rng(0)
    x = rand(rng, 256, 256)
    out = np.asarray(ew_k.relu(x))
    sp = np.mean(out == 0.0)
    assert 0.45 < sp < 0.55


@given(hw=st.integers(4, 10), c=st.integers(1, 6), k=st.sampled_from([3]),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_im2col_reconstructs_conv(hw, c, k, stride, seed):
    rng = np.random.default_rng(seed)
    cout = 5
    x = rand(rng, 1, hw, hw, c)
    w = rand(rng, k, k, c, cout)
    cols = np.asarray(conv_k.im2col(x, k, k, stride, k // 2))
    direct = np.asarray(ref.conv2d(x, w, stride, k // 2))
    via = cols @ w.reshape(-1, cout)
    np.testing.assert_allclose(via.reshape(direct.shape), direct,
                               rtol=1e-3, atol=1e-3)
