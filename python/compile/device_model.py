"""Python mirror of the rust heterogeneous device simulator
(rust/src/device/).  Reads the same config/devices.json and must implement
the same roofline latency equations — rust/tests/device_parity.rs checks a
golden table generated from this module.

Used at build time to label ground-truth scheduling thresholds (paper §3.3:
"one-time, offline exhaustive search on the target hardware"): for each
operator sample we sweep sparsity / intensity and find the boundary where
the optimal device flips.
"""
from __future__ import annotations

import json
import pathlib

_CFG = None


def load(path: str | None = None) -> dict:
    global _CFG
    if _CFG is None:
        p = pathlib.Path(path or pathlib.Path(__file__).resolve()
                         .parents[2] / "config" / "devices.json")
        _CFG = json.loads(p.read_text())
    return _CFG


# GPU effective-bandwidth ramp for small transfers (mirror of
# rust/src/device/mod.rs GPU_BW_RAMP_*; parity-tested).
GPU_BW_RAMP_BYTES = 4e6
GPU_BW_RAMP_FLOOR = 0.12


def op_latency_us(dev: dict, proc: str, op_class: str, flops: float,
                  bytes_moved: float, sparsity: float) -> float:
    """Roofline latency of one op on one processor, microseconds.

    t = max(eff_flops / rate, bytes / bw_eff) + launch
    eff_flops = flops * (1 - sparsity * elasticity[class])
    rate = peak * util[class]  (floored); GPU bandwidth ramps with size.
    """
    p = dev[proc]
    util = p["util"].get(op_class, p["util"]["other"])
    util = max(util, dev.get("min_util_floor", 0.02))
    elast = p["sparsity_elasticity"].get(op_class, 0.0)
    eff = flops * (1.0 - min(max(sparsity, 0.0), 1.0) * elast)
    t_compute = eff / (p["peak_gflops"] * util * 1e9) * 1e6
    bw = p["mem_bw_gbps"]
    if proc == "gpu":
        ramp = (bytes_moved / GPU_BW_RAMP_BYTES) ** 0.5
        bw *= min(max(ramp, GPU_BW_RAMP_FLOOR), 1.0)
    t_mem = bytes_moved / (bw * 1e9) * 1e6
    return max(t_compute, t_mem) + p["launch_overhead_us"]


def transfer_us(dev: dict, bytes_moved: float, pinned: bool = True,
                overlap: bool = False) -> float:
    t = dev["transfer"]
    lat = t["dma_latency_us"] + bytes_moved / (t["dma_bw_gbps"] * 1e9) * 1e6
    if not pinned:
        lat *= t["pageable_penalty"]
    if overlap:
        lat *= 1.0 - t["async_overlap"]
    return lat


def sparsity_threshold(dev: dict, op_class: str, flops: float,
                       bytes_moved: float, xfer_bytes: float) -> float:
    """Sparsity rho* where CPU and GPU placement cost cross (the CPU side
    gains from sparsity; the GPU side pays a transfer).  Found by bisection;
    0 means GPU always wins, 1 means CPU always wins."""
    def diff(rho):
        cpu = op_latency_us(dev, "cpu", op_class, flops, bytes_moved, rho)
        gpu = (op_latency_us(dev, "gpu", op_class, flops, bytes_moved, rho)
               + transfer_us(dev, xfer_bytes))
        return cpu - gpu
    lo, hi = 0.0, 1.0
    if diff(0.0) <= 0.0:
        return 0.0          # CPU already cheaper with no sparsity
    if diff(1.0) > 0.0:
        return 1.0          # GPU cheaper even fully sparse
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        if diff(mid) > 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


# Intensity thresholds are expressed in normalized log-FLOPs so they live in
# [0, 1] like the sparsity threshold (predictor output range).
LOG_FLOPS_MIN, LOG_FLOPS_MAX = 3.0, 12.0


def norm_intensity(flops: float) -> float:
    import math
    lf = math.log10(max(flops, 1.0))
    return min(max((lf - LOG_FLOPS_MIN) / (LOG_FLOPS_MAX - LOG_FLOPS_MIN),
                   0.0), 1.0)


def intensity_threshold(dev: dict, op_class: str, flops: float,
                        bytes_moved: float, sparsity: float,
                        xfer_bytes: float) -> float:
    """Normalized intensity I* where the optimal device flips when the op is
    scaled up/down (bytes scale with flops).  Bisection over scale factor."""
    def diff(scale):
        f, bts, xb = flops * scale, bytes_moved * scale, xfer_bytes * scale
        cpu = op_latency_us(dev, "cpu", op_class, f, bts, sparsity)
        gpu = (op_latency_us(dev, "gpu", op_class, f, bts, sparsity)
               + transfer_us(dev, xb))
        return gpu - cpu    # >0: CPU wins at this scale
    lo, hi = 1e-4, 1e4
    if diff(lo) <= 0.0:
        return norm_intensity(flops * lo)     # GPU wins even when tiny
    if diff(hi) > 0.0:
        return norm_intensity(flops * hi)     # CPU wins even when huge
    llo, lhi = lo, hi
    for _ in range(60):
        mid = (llo * lhi) ** 0.5
        if diff(mid) > 0.0:
            llo = mid
        else:
            lhi = mid
    return norm_intensity(flops * (llo * lhi) ** 0.5)
