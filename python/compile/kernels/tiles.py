"""Tiling helpers shared by the Pallas kernels.

TPU-shaped tiling policy (see DESIGN.md §Hardware-Adaptation): blocks are
sized for VMEM residency (<= ~2 MiB per operand tile) and MXU alignment
(multiples of 8x128 for f32 where the problem is big enough).  On the CPU
PJRT backend the kernels run under ``interpret=True`` so these choices shape
the HBM<->VMEM schedule rather than wall-clock; the block sizes below are the
ones we would ship on real hardware and are what the VMEM-footprint estimator
in ``python/compile/vmem.py`` audits.
"""
from __future__ import annotations

import math

# Default MXU-friendly tile sizes (f32).  Kept modest so interpret-mode
# lowering of exec-scale models stays fast.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m >= x."""
    return ((x + m - 1) // m) * m


def pick_block(dim: int, preferred: int) -> int:
    """Pick a block size: the preferred tile if the dim is large enough,
    otherwise the whole (small) dimension.  Always >= 1."""
    if dim >= preferred:
        return preferred
    return max(1, dim)


def grid_dim(total: int, block: int) -> int:
    return math.ceil(total / block)
