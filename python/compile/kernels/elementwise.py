"""L1 Pallas elementwise activation kernels (ReLU / HardSwish / GELU).

These are the sparsity *producers*: ReLU-family activations zero out a large
fraction of values, which the downstream sparse matmul/conv kernels gate on.
Each kernel is a single VPU pass over a row-blocked 2-D view.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiles


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...].astype(jnp.float32), 0.0)


def _hardswish_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def _relu6_kernel(x_ref, o_ref):
    o_ref[...] = jnp.clip(x_ref[...].astype(jnp.float32), 0.0, 6.0)


def _hardsigmoid_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    c = 0.7978845608028654  # sqrt(2/pi)
    o_ref[...] = 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def _rowblocked(kernel, x: jax.Array, br: int) -> jax.Array:
    rows, d = x.shape
    br = tiles.pick_block(rows, br)
    rp = tiles.round_up(rows, br)
    xp = jnp.pad(x.astype(jnp.float32), ((0, rp - rows), (0, 0)))
    out = pl.pallas_call(
        kernel,
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), jnp.float32),
        interpret=True,
    )(xp)
    return out[:rows]


@functools.partial(jax.jit, static_argnames=("br",))
def relu(x: jax.Array, *, br: int = 256) -> jax.Array:
    return _rowblocked(_relu_kernel, x, br)


@functools.partial(jax.jit, static_argnames=("br",))
def hardswish(x: jax.Array, *, br: int = 256) -> jax.Array:
    return _rowblocked(_hardswish_kernel, x, br)


@functools.partial(jax.jit, static_argnames=("br",))
def relu6(x: jax.Array, *, br: int = 256) -> jax.Array:
    return _rowblocked(_relu6_kernel, x, br)


@functools.partial(jax.jit, static_argnames=("br",))
def hardsigmoid(x: jax.Array, *, br: int = 256) -> jax.Array:
    return _rowblocked(_hardsigmoid_kernel, x, br)


@functools.partial(jax.jit, static_argnames=("br",))
def gelu(x: jax.Array, *, br: int = 256) -> jax.Array:
    return _rowblocked(_gelu_kernel, x, br)
