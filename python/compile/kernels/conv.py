"""L1 Pallas convolution kernels.

Standard convolution is expressed as im2col patch extraction followed by the
sparsity-aware blocked matmul (matmul.sparse_matmul) — the TPU-shaped
replacement for the paper's implicit-GEMM CUDA kernels: BlockSpec tiles play
the role threadblock shared-memory staging plays on GPU, and activation
sparsity (post-ReLU) gates whole MXU tiles instead of scattering rows.

Depthwise convolution gets its own kernel: it is memory-bound (no channel
reduction), so the kernel keeps a (H, W, cb) channel-block resident in VMEM
and accumulates the Kh*Kw shifted products over it — one HBM read of the
input per channel block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm
from . import tiles


def im2col(x: jax.Array, kh: int, kw: int, stride: int,
           padding: int) -> jax.Array:
    """Patch extraction, (N,H,W,C) -> (N*Ho*Wo, Kh*Kw*C).

    Pure data movement (slice + reshape); XLA fuses it into the consumer's
    HBM->VMEM pipeline, so it is not itself a Pallas kernel.
    Column order matches HWIO weight layout.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x.astype(jnp.float32),
                 [(0, 0), (padding, padding), (padding, padding), (0, 0)])
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1))
            cols.append(patch.reshape(n * ho * wo, c))
    return jnp.concatenate(cols, axis=-1)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
           padding: int = 0) -> jax.Array:
    """NHWC conv via im2col + sparse blocked matmul.

    x: (N,H,W,Cin), w: (Kh,Kw,Cin,Cout) -> (N,Ho,Wo,Cout).
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (wdt + 2 * padding - kw) // stride + 1
    cols = im2col(x, kh, kw, stride, padding)            # (N*Ho*Wo, Kh*Kw*Cin)
    wmat = w.astype(jnp.float32).reshape(kh * kw * cin, cout)
    out = mm.sparse_matmul(cols, wmat)                   # gated MXU tiles
    return out.reshape(n, ho, wo, cout)


def _dw_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, stride: int,
               ho: int, wo: int):
    """Depthwise block: input channel-block (Hp, Wp, cb) resident in VMEM;
    accumulate the Kh*Kw shifted elementwise products (unrolled at trace
    time — VPU work, no MXU)."""
    xv = x_ref[...].astype(jnp.float32)      # (Hp, Wp, cb)
    wv = w_ref[...].astype(jnp.float32)      # (kh, kw, cb)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xv, (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1,
                 xv.shape[2]),
                (stride, stride, 1))
            acc = acc + patch * wv[i, j][None, None, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "padding", "cb"))
def depthwise_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                     padding: int = 0, cb: int = 32) -> jax.Array:
    """Depthwise NHWC conv. x: (N,H,W,C), w: (Kh,Kw,C) -> (N,Ho,Wo,C).

    Grid: (N, C/cb); each step owns a full spatial slab of ``cb`` channels.
    """
    n, h, wdt, c = x.shape
    kh, kw, _ = w.shape
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (wdt + 2 * padding - kw) // stride + 1
    cb = tiles.pick_block(c, cb)
    cp = tiles.round_up(c, cb)
    xp = jnp.pad(x.astype(jnp.float32),
                 [(0, 0), (padding, padding), (padding, padding), (0, cp - c)])
    wp = jnp.pad(w.astype(jnp.float32), [(0, 0), (0, 0), (0, cp - c)])
    hp, wp_sp = h + 2 * padding, wdt + 2 * padding

    kern = functools.partial(_dw_kernel, kh=kh, kw=kw, stride=stride,
                             ho=ho, wo=wo)
    out = pl.pallas_call(
        kern,
        grid=(n, cp // cb),
        in_specs=[
            pl.BlockSpec((None, hp, wp_sp, cb),
                         lambda b, cc: (b, 0, 0, cc)),
            pl.BlockSpec((kh, kw, cb), lambda b, cc: (0, 0, cc)),
        ],
        out_specs=pl.BlockSpec((None, ho, wo, cb), lambda b, cc: (b, 0, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cp), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[..., :c]
