"""L1 Pallas matmul kernels: dense blocked matmul and the sparsity-aware
block-gated matmul that is SparOA's compute hot-spot.

Hardware adaptation (paper targets Ampere iGPU -> we target TPU-style
execution, DESIGN.md §Hardware-Adaptation):

* The dense kernel is a classic MXU-blocked matmul: the grid walks (M/bm,
  N/bn, K/bk) and each step pulls one (bm, bk) x (bk, bn) tile pair into
  VMEM via BlockSpec and accumulates in f32.

* The *sparse* kernel exploits activation sparsity the way a TPU can:
  PowerInfer-style GPU kernels scatter/gather individual nonzero rows, which
  the MXU cannot do.  Instead we gate whole (bm, bk) activation tiles — a
  tile that is entirely zero contributes nothing, so its MXU pass is
  predicated away (``pl.when`` on a tile-nonzero flag).  With post-ReLU
  activation sparsity rho, the expected fraction of skipped MXU passes is
  ~rho for block-aligned sparsity, which is what the device model's
  ``sparsity_elasticity`` captures.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiles


def _dense_kernel(x_ref, y_ref, o_ref):
    """One grid step of the blocked matmul: accumulate x_tile @ y_tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _sparse_kernel(x_ref, y_ref, o_ref):
    """Block-gated step: skip the MXU pass when the activation tile is all
    zero.  ``pl.when`` predicates the accumulate, which is the TPU analogue
    of skipping a threadblock on GPU."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_tile = x_ref[...].astype(jnp.float32)
    tile_nonzero = jnp.any(x_tile != 0.0)

    @pl.when(tile_nonzero)
    def _acc():
        o_ref[...] += jnp.dot(
            x_tile, y_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )


def _blocked_call(kernel, x: jax.Array, y: jax.Array,
                  bm: int, bn: int, bk: int) -> jax.Array:
    """Pad to block multiples, run the 3-D grid, slice the result back."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul shape mismatch: {x.shape} @ {y.shape}"
    mp, np_, kp = (tiles.round_up(m, bm), tiles.round_up(n, bn),
                   tiles.round_up(k, bk))
    xpad = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    ypad = jnp.pad(y.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xpad, ypad)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = tiles.BLOCK_M,
           bn: int = tiles.BLOCK_N, bk: int = tiles.BLOCK_K) -> jax.Array:
    """Dense blocked Pallas matmul, (M,K) @ (K,N) -> (M,N) f32."""
    m, k = x.shape
    _, n = y.shape
    bm = tiles.pick_block(m, bm)
    bn = tiles.pick_block(n, bn)
    bk = tiles.pick_block(k, bk)
    return _blocked_call(_dense_kernel, x, y, bm, bn, bk)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def sparse_matmul(x: jax.Array, y: jax.Array, *, bm: int = tiles.BLOCK_M,
                  bn: int = tiles.BLOCK_N,
                  bk: int = tiles.BLOCK_K) -> jax.Array:
    """Sparsity-aware block-gated Pallas matmul.

    Numerically identical to :func:`matmul` (zero tiles contribute zero);
    on real hardware the gated tiles skip their MXU pass entirely.
    """
    m, k = x.shape
    _, n = y.shape
    bm = tiles.pick_block(m, bm)
    bn = tiles.pick_block(n, bn)
    bk = tiles.pick_block(k, bk)
    return _blocked_call(_sparse_kernel, x, y, bm, bn, bk)


@jax.jit
def linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Affine layer on the sparse kernel: sparse_matmul(x, w) + b."""
    return sparse_matmul(x, w) + b.astype(jnp.float32)
