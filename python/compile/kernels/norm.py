"""L1 Pallas normalization kernels: LayerNorm and inference BatchNorm.

Both are memory-bound: the tiling keeps a (rows, D) slab in VMEM, computes
the row statistics on the VPU and writes the normalized slab back — one HBM
round-trip per element, which is the roofline for these ops.  (This is why
the device model marks them CPU-friendly: on the GPU they are pure
launch + bandwidth cost.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiles


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (br, D)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("br",))
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5, *, br: int = 128) -> jax.Array:
    """LayerNorm over the last axis of a 2-D input (rows, D)."""
    rows, d = x.shape
    br = tiles.pick_block(rows, br)
    rp = tiles.round_up(rows, br)
    xp = jnp.pad(x.astype(jnp.float32), ((0, rp - rows), (0, 0)))
    kern = functools.partial(_layernorm_kernel, eps=eps)
    out = pl.pallas_call(
        kern,
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), jnp.float32),
        interpret=True,
    )(xp, gamma.astype(jnp.float32), beta.astype(jnp.float32))
    return out[:rows]


def _batchnorm_kernel(x_ref, scale_ref, shift_ref, o_ref):
    # scale/shift are precomputed outside: scale = gamma*rsqrt(var+eps),
    # shift = beta - mean*scale.  The kernel is a pure fused multiply-add.
    o_ref[...] = (x_ref[...].astype(jnp.float32) * scale_ref[...]
                  + shift_ref[...])


@functools.partial(jax.jit, static_argnames=("br",))
def batchnorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              mean: jax.Array, var: jax.Array, eps: float = 1e-5,
              *, br: int = 256) -> jax.Array:
    """Inference BatchNorm on a 2-D view (rows, C); channel axis last."""
    rows, c = x.shape
    scale = (gamma * jax.lax.rsqrt(var.astype(jnp.float32) + eps))
    shift = beta - mean * scale
    br = tiles.pick_block(rows, br)
    rp = tiles.round_up(rows, br)
    xp = jnp.pad(x.astype(jnp.float32), ((0, rp - rows), (0, 0)))
    out = pl.pallas_call(
        _batchnorm_kernel,
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), jnp.float32),
        interpret=True,
    )(xp, scale.astype(jnp.float32), shift.astype(jnp.float32))
    return out[:rows]
