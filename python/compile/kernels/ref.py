"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the correctness ground truth: each Pallas kernel in this package
must match its oracle to float32 tolerance under pytest/hypothesis sweeps
(python/tests/test_kernels.py).  The oracles are deliberately written in the
most obvious jnp form — no tiling, no tricks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Dense matmul oracle: (M, K) @ (K, N) -> (M, N), f32 accumulation."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Affine oracle: x @ w + b."""
    return matmul(x, w) + b.astype(jnp.float32)


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           padding: int = 0) -> jax.Array:
    """NHWC conv oracle. x: (N,H,W,Cin), w: (Kh,Kw,Cin,Cout)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def depthwise_conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
                     padding: int = 0) -> jax.Array:
    """Depthwise NHWC conv oracle. x: (N,H,W,C), w: (Kh,Kw,C)."""
    c = x.shape[-1]
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w[:, :, None, :].astype(jnp.float32),  # (Kh,Kw,1,C): I=1, O=C
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    """LayerNorm oracle over the last axis."""
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def batchnorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              mean: jax.Array, var: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Inference-mode BatchNorm oracle (per-channel affine on last axis)."""
    x = x.astype(jnp.float32)
    scale = gamma * jax.lax.rsqrt(var + eps)
    return x * scale + (beta - mean * scale)


def softmax(x: jax.Array) -> jax.Array:
    """Numerically-stable softmax oracle over the last axis."""
    x = x.astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-head scaled-dot-product attention oracle.

    q,k,v: (T, d) -> (T, d).
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = q @ k.T * scale
    return softmax(logits) @ v


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x.astype(jnp.float32), 0.0)


def hardswish(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    return x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def relu6(x: jax.Array) -> jax.Array:
    return jnp.clip(x.astype(jnp.float32), 0.0, 6.0)


def hardsigmoid(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    return jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU oracle (what the Pallas kernel implements)."""
    x = x.astype(jnp.float32)
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def avgpool2d(x: jax.Array, window: int, stride: int) -> jax.Array:
    """NHWC average pool oracle."""
    x = x.astype(jnp.float32)
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")
    return s / float(window * window)


def maxpool2d(x: jax.Array, window: int, stride: int, padding: int = 0) -> jax.Array:
    """NHWC max pool oracle."""
    x = x.astype(jnp.float32)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1),
        [(0, 0), (padding, padding), (padding, padding), (0, 0)])


def im2col(x: jax.Array, kh: int, kw: int, stride: int,
           padding: int) -> jax.Array:
    """Patch extraction oracle: (N,H,W,C) -> (N*Ho*Wo, Kh*Kw*C).

    Column order matches conv2d's HWIO weight layout so that
    im2col(x) @ w.reshape(-1, Cout) == conv2d(x, w).
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x.astype(jnp.float32),
                 [(0, 0), (padding, padding), (padding, padding), (0, 0)])
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1))
            cols.append(patch.reshape(n * ho * wo, c))
    return jnp.concatenate(cols, axis=-1)
