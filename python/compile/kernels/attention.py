"""L1 Pallas attention + softmax kernels.

The attention kernel fuses q@k^T -> stable softmax -> @v for one head in a
single VMEM-resident pass (the sequence lengths of the edge vision models —
<= 197 tokens at paper scale — fit comfortably, so no online-softmax
streaming is needed; the whole (T, d) tile is the block).  The grid walks
the fused batch*heads axis, which is the TPU analogue of assigning one
(batch, head) to a CUDA threadblock.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiles


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("br",))
def softmax(x: jax.Array, *, br: int = 128) -> jax.Array:
    """Row-blocked stable softmax over the last axis of (rows, D)."""
    rows, d = x.shape
    br = tiles.pick_block(rows, br)
    rp = tiles.round_up(rows, br)
    # Pad with -inf-ish so padded rows don't produce NaNs (they are sliced
    # away, but interpret-mode still computes them).
    xp = jnp.pad(x.astype(jnp.float32), ((0, rp - rows), (0, 0)),
                 constant_values=0.0)
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), jnp.float32),
        interpret=True,
    )(xp)
    return out[:rows]


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32)        # (T, d)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Batched fused attention: q,k,v (BH, T, d) -> (BH, T, d)."""
    bh, t, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    kern = functools.partial(_attention_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((None, t, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, t, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, t, d), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, t, d), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
