"""Exec-scale graph interpreter: runs a model graph op-by-op through the L1
Pallas kernels (interpret=True) and measures per-op activation sparsity.

This is the build-time profiling pass of SparOA's offline phase: the
sparsity statistics recorded here are what the threshold predictor and the
RL scheduler consume (the rust side reads them from topology.json).
"""
from __future__ import annotations

import numpy as np

from .graph_ir import Graph, op_callable


def run(g: Graph, params: list[list[np.ndarray]], x: np.ndarray,
        collect: bool = True):
    """Execute graph on input x. Returns (final_output, sparsity_out[])"""
    vals: dict[int, np.ndarray] = {}
    sparsity = np.zeros(len(g.ops), np.float64)
    for op in g.ops:
        if op.kind == "input":
            out = x
        else:
            fn = op_callable(op)
            ins = [vals[i] for i in op.inputs]
            out = np.asarray(fn(ins, params[op.id]))
        assert tuple(out.shape) == op.out_shape, \
            (g.model, op.name, out.shape, op.out_shape)
        vals[op.id] = out
        if collect:
            sparsity[op.id] = float(np.mean(np.abs(out) < 1e-9))
        # free dead values
        last_use = op.id
        for later in g.ops[op.id + 1:]:
            if op.id in later.inputs:
                last_use = later.id
        if last_use == op.id and op.id != g.ops[-1].id:
            pass  # small models; keep everything (also used by tests)
    return vals[g.ops[-1].id], sparsity


def measure_sparsity(g: Graph, params, n_inputs: int = 3,
                     seed: int = 7) -> np.ndarray:
    """Mean per-op output sparsity over several random inputs."""
    from . import datagen
    acc = np.zeros(len(g.ops), np.float64)
    for i in range(n_inputs):
        x = datagen.sample_input(g.input_shape, seed=seed + i)
        _, sp = run(g, params, x, collect=True)
        acc += sp
    return acc / n_inputs
