"""Synthetic weights and inputs (substitution for ImageNet/COCO pretrained
models — see DESIGN.md §2).

Weights are He-normal; BatchNorm betas get a per-layer offset drawn from a
wide range so post-ReLU activation sparsity spans the 0–0.9 band the paper
observes on real pretrained models (Fig. 2).  The scheduling problem only
sees (sparsity, intensity, shapes), so this preserves the behaviour that
matters.
"""
from __future__ import annotations

import math

import numpy as np

from .graph_ir import Graph, Op


def init_params(g: Graph, seed: int = 0) -> list[list[np.ndarray]]:
    """Per-op parameter arrays for one graph.  Returns params[op_id]."""
    rng = np.random.default_rng(seed)
    all_params: list[list[np.ndarray]] = []
    for op in g.ops:
        ps: list[np.ndarray] = []
        if op.kind in ("conv2d", "dwconv"):
            shape = op.param_shapes[0]
            fan_in = math.prod(shape[:-1]) if op.kind == "conv2d" else \
                shape[0] * shape[1]
            w = rng.standard_normal(shape).astype(np.float32)
            w *= math.sqrt(2.0 / max(fan_in, 1))
            ps.append(w)
        elif op.kind == "linear":
            wshape, bshape = op.param_shapes
            w = rng.standard_normal(wshape).astype(np.float32)
            w *= math.sqrt(2.0 / wshape[0])
            ps.append(w)
            ps.append(np.zeros(bshape, np.float32))
        elif op.kind == "batchnorm":
            c = op.param_shapes[0][0]
            gamma = rng.uniform(0.6, 1.4, c).astype(np.float32)
            # Per-layer sparsity knob: shifts the pre-activation
            # distribution; the following ReLU turns it into activation
            # sparsity anywhere between ~0.15 and ~0.9.
            offset = rng.uniform(-1.3, 0.6)
            beta = (rng.standard_normal(c) * 0.2 + offset).astype(np.float32)
            mean = np.zeros(c, np.float32)
            var = np.ones(c, np.float32)
            ps.extend([gamma, beta, mean, var])
        elif op.kind == "layernorm":
            c = op.param_shapes[0][0]
            ps.append(rng.uniform(0.8, 1.2, c).astype(np.float32))
            ps.append((rng.standard_normal(c) * 0.1).astype(np.float32))
        all_params.append(ps)
    return all_params


def flatten_params(all_params: list[list[np.ndarray]]):
    """Concatenate every op's params into one f32 buffer; return the buffer
    and per-op slice records [{offset, numel, shape}]."""
    blobs = []
    slices: list[list[dict]] = []
    offset = 0
    for ps in all_params:
        recs = []
        for p in ps:
            flat = np.ascontiguousarray(p, np.float32).reshape(-1)
            recs.append({"offset": offset, "numel": int(flat.size),
                         "shape": list(p.shape)})
            blobs.append(flat)
            offset += flat.size
        slices.append(recs)
    buf = np.concatenate(blobs) if blobs else np.zeros(0, np.float32)
    return buf, slices


def sample_input(shape, seed: int = 0) -> np.ndarray:
    """ImageNet-ish normalized image batch: zero-mean unit-var channels."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)
