"""Operator-graph IR shared by the model builders and the AOT exporter.

Each DNN model is built as a flat list of :class:`Op` nodes in topological
order.  Every model is built **twice** from the same builder code — once at
*exec* scale (small shapes; these get HLO artifacts and run through PJRT in
rust) and once at *paper* scale (the shapes from the paper's Table 2; these
drive the device simulator and every figure reproduction).  The two builds
must produce identical op sequences; ``zip_scales`` asserts that and merges
them into the topology JSON the rust side loads.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as attn_k
from .kernels import conv as conv_k
from .kernels import elementwise as ew_k
from .kernels import matmul as mm_k
from .kernels import norm as norm_k

# Op kinds understood by the rust coordinator (rust/src/graph/op.rs must
# stay in sync).
KINDS = (
    "input", "conv2d", "dwconv", "linear", "matmul", "batchnorm",
    "layernorm", "relu", "relu6", "hardswish", "hardsigmoid", "gelu",
    "softmax", "attention", "add", "mul", "maxpool", "avgpool",
    "globalavgpool", "reshape", "roll", "concat", "window_part",
    "window_rev", "space_to_depth",
)

# Device-model op classes (keys of util/sparsity_elasticity in devices.json).
KIND_CLASS = {
    "conv2d": "conv", "dwconv": "dwconv", "linear": "matmul",
    "matmul": "matmul", "attention": "attention", "batchnorm": "norm",
    "layernorm": "norm", "relu": "elementwise", "relu6": "elementwise",
    "hardswish": "elementwise", "hardsigmoid": "elementwise",
    "gelu": "elementwise", "softmax": "softmax", "add": "elementwise",
    "mul": "elementwise", "maxpool": "pool", "avgpool": "pool",
    "globalavgpool": "pool", "reshape": "other", "roll": "other",
    "concat": "other", "input": "other", "window_part": "other",
    "window_rev": "other", "space_to_depth": "other",
}


@dataclasses.dataclass
class Op:
    """One operator node (single scale)."""
    id: int
    name: str
    kind: str
    inputs: list[int]                      # producer op ids
    attrs: dict[str, Any]
    in_shapes: list[tuple[int, ...]]
    out_shape: tuple[int, ...]
    param_shapes: list[tuple[int, ...]]
    flops: float = 0.0


@dataclasses.dataclass
class Graph:
    model: str
    scale: str                             # "exec" | "paper"
    input_shape: tuple[int, ...]
    ops: list[Op] = dataclasses.field(default_factory=list)


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def flops_for(kind: str, attrs: dict, in_shapes, out_shape,
              param_shapes) -> float:
    """Analytic FLOP count per op kind (2*MACs for contractions)."""
    n_out = _numel(out_shape)
    n_in = sum(_numel(s) for s in in_shapes)
    if kind == "conv2d":
        kh, kw = attrs["kh"], attrs["kw"]
        cin, cout = attrs["cin"], attrs["cout"]
        return 2.0 * kh * kw * cin * cout * out_shape[-3] * out_shape[-2] * out_shape[0]
    if kind == "dwconv":
        kh, kw = attrs["kh"], attrs["kw"]
        return 2.0 * kh * kw * n_out
    if kind in ("linear", "matmul"):
        k = in_shapes[0][-1]
        return 2.0 * k * n_out
    if kind == "attention":
        b, t, three_c = in_shapes[0]
        c = three_c // 3
        return 4.0 * b * t * t * c + 5.0 * b * attrs["heads"] * t * t
    if kind == "batchnorm":
        return 2.0 * n_out
    if kind == "layernorm":
        return 8.0 * n_out
    if kind in ("relu", "relu6"):
        return 1.0 * n_out
    if kind in ("hardswish", "hardsigmoid"):
        return 4.0 * n_out
    if kind == "gelu":
        return 9.0 * n_out
    if kind == "softmax":
        return 5.0 * n_out
    if kind in ("add", "mul"):
        return 1.0 * n_out
    if kind in ("maxpool", "avgpool"):
        return float(attrs.get("window", 2) ** 2) * n_out
    if kind == "globalavgpool":
        return float(n_in)
    return 0.0  # reshape / roll / concat / input: data movement only


class GraphBuilder:
    """Builds one scale of a model.  Helper methods append ops, compute
    output shapes as they go, and return the new op id."""

    def __init__(self, model: str, scale: str, input_shape):
        self.g = Graph(model=model, scale=scale,
                       input_shape=tuple(input_shape))
        inp = Op(0, "input", "input", [], {}, [], tuple(input_shape), [])
        self.g.ops.append(inp)

    def _add(self, name, kind, inputs, attrs, in_shapes, out_shape,
             param_shapes) -> int:
        op = Op(len(self.g.ops), name, kind, list(inputs), dict(attrs),
                [tuple(s) for s in in_shapes], tuple(out_shape),
                [tuple(s) for s in param_shapes])
        op.flops = flops_for(kind, attrs, op.in_shapes, op.out_shape,
                             op.param_shapes)
        self.g.ops.append(op)
        return op.id

    def shape(self, op_id: int) -> tuple[int, ...]:
        return self.g.ops[op_id].out_shape

    # -- builders ----------------------------------------------------------
    def conv2d(self, x, cout, k, stride=1, padding=None, name="conv"):
        n, h, w, cin = self.shape(x)
        if padding is None:
            padding = k // 2
        ho = (h + 2 * padding - k) // stride + 1
        wo = (w + 2 * padding - k) // stride + 1
        attrs = dict(kh=k, kw=k, stride=stride, padding=padding,
                     cin=cin, cout=cout)
        return self._add(name, "conv2d", [x], attrs, [self.shape(x)],
                         (n, ho, wo, cout), [(k, k, cin, cout)])

    def dwconv(self, x, k, stride=1, padding=None, name="dwconv"):
        n, h, w, c = self.shape(x)
        if padding is None:
            padding = k // 2
        ho = (h + 2 * padding - k) // stride + 1
        wo = (w + 2 * padding - k) // stride + 1
        attrs = dict(kh=k, kw=k, stride=stride, padding=padding, cin=c,
                     cout=c)
        return self._add(name, "dwconv", [x], attrs, [self.shape(x)],
                         (n, ho, wo, c), [(k, k, c)])

    def linear(self, x, dout, name="linear"):
        s = self.shape(x)
        k = s[-1]
        out = s[:-1] + (dout,)
        return self._add(name, "linear", [x], dict(din=k, dout=dout),
                         [s], out, [(k, dout), (dout,)])

    def batchnorm(self, x, name="bn"):
        s = self.shape(x)
        c = s[-1]
        return self._add(name, "batchnorm", [x], dict(c=c), [s], s,
                         [(c,), (c,), (c,), (c,)])

    def layernorm(self, x, name="ln"):
        s = self.shape(x)
        c = s[-1]
        return self._add(name, "layernorm", [x], dict(c=c), [s], s,
                         [(c,), (c,)])

    def act(self, x, kind, name=None):
        s = self.shape(x)
        return self._add(name or kind, kind, [x], {}, [s], s, [])

    def softmax(self, x, name="softmax"):
        s = self.shape(x)
        return self._add(name, "softmax", [x], {}, [s], s, [])

    def attention(self, x, heads, name="attn"):
        """x: (B, T, 3C) packed qkv -> (B, T, C)."""
        b, t, three_c = self.shape(x)
        c = three_c // 3
        return self._add(name, "attention", [x], dict(heads=heads),
                         [self.shape(x)], (b, t, c), [])

    def add(self, a, b, name="add"):
        s = self.shape(a)
        assert s == self.shape(b), (s, self.shape(b), name)
        return self._add(name, "add", [a, b], {}, [s, s], s, [])

    def mul(self, a, b, name="mul"):
        """Broadcast multiply: a (N,H,W,C) * b (N,1,1,C) or same-shape."""
        s = self.shape(a)
        return self._add(name, "mul", [a, b], {},
                         [s, self.shape(b)], s, [])

    def maxpool(self, x, window, stride, padding=0, name="maxpool"):
        n, h, w, c = self.shape(x)
        ho = (h + 2 * padding - window) // stride + 1
        wo = (w + 2 * padding - window) // stride + 1
        return self._add(name, "maxpool", [x],
                         dict(window=window, stride=stride, padding=padding),
                         [self.shape(x)], (n, ho, wo, c), [])

    def avgpool(self, x, window, stride, name="avgpool"):
        n, h, w, c = self.shape(x)
        ho = (h - window) // stride + 1
        wo = (w - window) // stride + 1
        return self._add(name, "avgpool", [x],
                         dict(window=window, stride=stride),
                         [self.shape(x)], (n, ho, wo, c), [])

    def globalavgpool(self, x, keepdims=False, name="gap"):
        n, h, w, c = self.shape(x)
        out = (n, 1, 1, c) if keepdims else (n, c)
        return self._add(name, "globalavgpool", [x],
                         dict(keepdims=int(keepdims)), [self.shape(x)], out, [])

    def reshape(self, x, out_shape, name="reshape"):
        assert _numel(self.shape(x)) == _numel(out_shape), \
            (self.shape(x), out_shape, name)
        return self._add(name, "reshape", [x], {}, [self.shape(x)],
                         tuple(out_shape), [])

    def roll(self, x, shift_h, shift_w, name="roll"):
        """Cyclic shift on (B, H, W, C) — Swin shifted windows."""
        s = self.shape(x)
        return self._add(name, "roll", [x],
                         dict(shift_h=shift_h, shift_w=shift_w), [s], s, [])

    def window_part(self, x, win, name="wpart"):
        """(B, H, W, C) -> (B * H/win * W/win, win*win, C)."""
        n, h, w, c = self.shape(x)
        nw = (h // win) * (w // win)
        return self._add(name, "window_part", [x], dict(win=win),
                         [self.shape(x)], (n * nw, win * win, c), [])

    def window_rev(self, x, win, h, w, name="wrev"):
        """(B*nW, win*win, C) -> (B, H, W, C)."""
        bn, t, c = self.shape(x)
        nw = (h // win) * (w // win)
        return self._add(name, "window_rev", [x],
                         dict(win=win, h=h, w=w), [self.shape(x)],
                         (bn // nw, h, w, c), [])

    def space_to_depth(self, x, name="s2d"):
        """(B, H, W, C) -> (B, H/2, W/2, 4C) — Swin patch merging."""
        n, h, w, c = self.shape(x)
        return self._add(name, "space_to_depth", [x], {},
                         [self.shape(x)], (n, h // 2, w // 2, 4 * c), [])

    def concat(self, xs, axis, name="concat"):
        shapes = [self.shape(x) for x in xs]
        out = list(shapes[0])
        out[axis] = sum(s[axis] for s in shapes)
        return self._add(name, "concat", list(xs), dict(axis=axis),
                         shapes, tuple(out), [])


# ---------------------------------------------------------------------------
# Per-kind jax callables (exec scale): fn(inputs, params) -> output.
# These are what get AOT-lowered to HLO artifacts and what the python-side
# interpreter runs to measure activation sparsity.
# ---------------------------------------------------------------------------

def _as2d(x):
    return x.reshape(-1, x.shape[-1])


def op_fn(kind: str, attrs: dict) -> Callable:
    if kind == "conv2d":
        st, pad = attrs["stride"], attrs["padding"]
        return lambda ins, ps: conv_k.conv2d(ins[0], ps[0], stride=st,
                                             padding=pad)
    if kind == "dwconv":
        st, pad = attrs["stride"], attrs["padding"]
        return lambda ins, ps: conv_k.depthwise_conv2d(ins[0], ps[0],
                                                       stride=st, padding=pad)
    if kind == "linear":
        def f(ins, ps):
            x = ins[0]
            y = mm_k.linear(_as2d(x), ps[0], ps[1])
            return y.reshape(x.shape[:-1] + (ps[0].shape[1],))
        return f
    if kind == "batchnorm":
        def f(ins, ps):
            x = ins[0]
            y = norm_k.batchnorm(_as2d(x), ps[0], ps[1], ps[2], ps[3])
            return y.reshape(x.shape)
        return f
    if kind == "layernorm":
        def f(ins, ps):
            x = ins[0]
            y = norm_k.layernorm(_as2d(x), ps[0], ps[1])
            return y.reshape(x.shape)
        return f
    if kind in ("relu", "relu6", "hardswish", "hardsigmoid", "gelu"):
        ew = getattr(ew_k, kind)
        def f(ins, ps):
            x = ins[0]
            return ew(_as2d(x)).reshape(x.shape)
        return f
    if kind == "softmax":
        def f(ins, ps):
            x = ins[0]
            return attn_k.softmax(_as2d(x)).reshape(x.shape)
        return f
    if kind == "attention":
        heads = attrs["heads"]
        def f(ins, ps):
            x = ins[0]                                   # (B, T, 3C)
            b, t, three_c = x.shape
            c = three_c // 3
            d = c // heads
            qkv = x.reshape(b, t, 3, heads, d)
            q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(b * heads, t, d)
            k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(b * heads, t, d)
            v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(b * heads, t, d)
            o = attn_k.attention(q, k, v)                # (B*H, T, d)
            o = o.reshape(b, heads, t, d).transpose(0, 2, 1, 3)
            return o.reshape(b, t, c)
        return f
    if kind == "add":
        return lambda ins, ps: ins[0] + ins[1]
    if kind == "mul":
        return lambda ins, ps: ins[0] * ins[1]
    if kind == "maxpool":
        w, s, p = attrs["window"], attrs["stride"], attrs["padding"]
        from .kernels import ref as ref_k
        return lambda ins, ps: ref_k.maxpool2d(ins[0], w, s, p)
    if kind == "avgpool":
        w, s = attrs["window"], attrs["stride"]
        from .kernels import ref as ref_k
        return lambda ins, ps: ref_k.avgpool2d(ins[0], w, s)
    if kind == "globalavgpool":
        keep = bool(attrs.get("keepdims", 0))
        def f(ins, ps):
            y = jnp.mean(ins[0], axis=(1, 2), keepdims=keep)
            return y
        return f
    if kind == "reshape":
        return None  # shape comes from the op record; handled by caller
    if kind == "roll":
        sh, sw = attrs["shift_h"], attrs["shift_w"]
        return lambda ins, ps: jnp.roll(ins[0], (sh, sw), axis=(1, 2))
    if kind == "concat":
        ax = attrs["axis"]
        return lambda ins, ps: jnp.concatenate(ins, axis=ax)
    if kind == "window_part":
        win = attrs["win"]
        def f(ins, ps):
            x = ins[0]
            n, h, w, c = x.shape
            x = x.reshape(n, h // win, win, w // win, win, c)
            x = x.transpose(0, 1, 3, 2, 4, 5)
            return x.reshape(-1, win * win, c)
        return f
    if kind == "window_rev":
        win, h, w = attrs["win"], attrs["h"], attrs["w"]
        def f(ins, ps):
            x = ins[0]
            c = x.shape[-1]
            n = x.shape[0] // ((h // win) * (w // win))
            x = x.reshape(n, h // win, w // win, win, win, c)
            x = x.transpose(0, 1, 3, 2, 4, 5)
            return x.reshape(n, h, w, c)
        return f
    if kind == "space_to_depth":
        def f(ins, ps):
            x = ins[0]
            n, h, w, c = x.shape
            x = x.reshape(n, h // 2, 2, w // 2, 2, c)
            x = x.transpose(0, 1, 3, 2, 4, 5)
            return x.reshape(n, h // 2, w // 2, 4 * c)
        return f
    raise ValueError(f"no op_fn for kind {kind}")


def op_callable(op: Op) -> Callable:
    """Concrete jax callable for an exec-scale op (reshape resolved here)."""
    if op.kind == "reshape":
        out = op.out_shape
        return lambda ins, ps: ins[0].reshape(out)
    return op_fn(op.kind, op.attrs)


def signature(op: Op) -> str:
    """Unique artifact signature for an exec-scale op."""
    key = json.dumps([op.kind, sorted(op.attrs.items()),
                      op.in_shapes, list(op.out_shape), op.param_shapes],
                     default=str)
    h = hashlib.sha1(key.encode()).hexdigest()[:12]
    return f"{op.kind}_{h}"


def zip_scales(exec_g: Graph, paper_g: Graph) -> None:
    """Assert the two scales describe the same op sequence."""
    assert len(exec_g.ops) == len(paper_g.ops), \
        (exec_g.model, len(exec_g.ops), len(paper_g.ops))
    for a, b in zip(exec_g.ops, paper_g.ops):
        assert a.kind == b.kind and a.name == b.name and a.inputs == b.inputs, \
            (exec_g.model, a.id, a.kind, b.kind, a.name, b.name)
