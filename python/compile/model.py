"""L2 model definitions: the five DNN models of the paper's Table 2, built
as flat operator graphs (graph_ir.GraphBuilder) that call the L1 Pallas
kernels.

Every model has two scale configs:

* ``paper`` — the shapes the paper evaluates (ImageNet-resolution inputs,
  full widths).  Only shapes/FLOPs are computed at this scale; they drive
  the device simulator and all figure reproductions.
* ``exec`` — reduced resolution/width.  These ops are AOT-lowered to HLO
  artifacts and actually executed through PJRT by the rust engine.

Both scales are emitted by the same builder code so the op sequences are
identical (graph_ir.zip_scales asserts it).
"""
from __future__ import annotations

import dataclasses

from .graph_ir import Graph, GraphBuilder


def _mkdiv(v: float, d: int = 8) -> int:
    """Round channel counts like the MobileNet papers do."""
    n = max(d, int(v + d / 2) // d * d)
    if n < 0.9 * v:
        n += d
    return n


# ---------------------------------------------------------------------------
# ResNet-18
# ---------------------------------------------------------------------------

def build_resnet18(scale: str) -> Graph:
    if scale == "paper":
        img, widths = 224, (64, 128, 256, 512)
    else:
        img, widths = 32, (16, 32, 64, 128)
    b = GraphBuilder("resnet18", scale, (1, img, img, 3))
    x = b.conv2d(0, widths[0], 7, stride=2, padding=3, name="stem.conv")
    x = b.batchnorm(x, name="stem.bn")
    x = b.act(x, "relu", name="stem.relu")
    x = b.maxpool(x, 3, 2, padding=1, name="stem.maxpool")

    for si, c in enumerate(widths):
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            pfx = f"layer{si + 1}.{bi}"
            identity = x
            y = b.conv2d(x, c, 3, stride=stride, name=f"{pfx}.conv1")
            y = b.batchnorm(y, name=f"{pfx}.bn1")
            y = b.act(y, "relu", name=f"{pfx}.relu1")
            y = b.conv2d(y, c, 3, name=f"{pfx}.conv2")
            y = b.batchnorm(y, name=f"{pfx}.bn2")
            if b.shape(identity) != b.shape(y):
                identity = b.conv2d(identity, c, 1, stride=stride,
                                    padding=0, name=f"{pfx}.down.conv")
                identity = b.batchnorm(identity, name=f"{pfx}.down.bn")
            y = b.add(y, identity, name=f"{pfx}.add")
            x = b.act(y, "relu", name=f"{pfx}.relu2")

    x = b.globalavgpool(x, name="head.gap")
    x = b.linear(x, 1000 if scale == "paper" else 10, name="head.fc")
    return b.g


# ---------------------------------------------------------------------------
# MobileNetV2
# ---------------------------------------------------------------------------

_MBV2_SPEC = [
    # t (expand), c (out), n (repeats), s (stride)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


def build_mobilenet_v2(scale: str) -> Graph:
    if scale == "paper":
        img, wm, head_c = 224, 1.0, 1280
    else:
        img, wm, head_c = 32, 0.35, 160
    b = GraphBuilder("mobilenet_v2", scale, (1, img, img, 3))
    c_stem = _mkdiv(32 * wm)
    x = b.conv2d(0, c_stem, 3, stride=2, name="stem.conv")
    x = b.batchnorm(x, name="stem.bn")
    x = b.act(x, "relu6", name="stem.relu6")

    cin, cin_spec = c_stem, 32
    blk = 0
    for t, c, n, s in _MBV2_SPEC:
        cout = _mkdiv(c * wm)
        for i in range(n):
            stride = s if i == 0 else 1
            pfx = f"block{blk}"
            identity = x
            y = x
            hidden = cin * t
            if t != 1:
                y = b.conv2d(y, hidden, 1, padding=0, name=f"{pfx}.expand")
                y = b.batchnorm(y, name=f"{pfx}.expand.bn")
                y = b.act(y, "relu6", name=f"{pfx}.expand.relu6")
            y = b.dwconv(y, 3, stride=stride, name=f"{pfx}.dw")
            y = b.batchnorm(y, name=f"{pfx}.dw.bn")
            y = b.act(y, "relu6", name=f"{pfx}.dw.relu6")
            y = b.conv2d(y, cout, 1, padding=0, name=f"{pfx}.project")
            y = b.batchnorm(y, name=f"{pfx}.project.bn")
            if stride == 1 and cin_spec == c:
                y = b.add(y, identity, name=f"{pfx}.add")
            x, cin, cin_spec = y, cout, c
            blk += 1

    x = b.conv2d(x, head_c, 1, padding=0, name="head.conv")
    x = b.batchnorm(x, name="head.bn")
    x = b.act(x, "relu6", name="head.relu6")
    x = b.globalavgpool(x, name="head.gap")
    x = b.linear(x, 1000 if scale == "paper" else 10, name="head.fc")
    return b.g


# ---------------------------------------------------------------------------
# MobileNetV3-Small
# ---------------------------------------------------------------------------

_MBV3S_SPEC = [
    # k, exp, out, use_se, activation, stride
    (3, 16, 16, True, "relu", 2),
    (3, 72, 24, False, "relu", 2),
    (3, 88, 24, False, "relu", 1),
    (5, 96, 40, True, "hardswish", 2),
    (5, 240, 40, True, "hardswish", 1),
    (5, 240, 40, True, "hardswish", 1),
    (5, 120, 48, True, "hardswish", 1),
    (5, 144, 48, True, "hardswish", 1),
    (5, 288, 96, True, "hardswish", 2),
    (5, 576, 96, True, "hardswish", 1),
    (5, 576, 96, True, "hardswish", 1),
]


def build_mobilenet_v3_small(scale: str) -> Graph:
    if scale == "paper":
        img, wm = 224, 1.0
    else:
        img, wm = 32, 0.5
    b = GraphBuilder("mobilenet_v3_small", scale, (1, img, img, 3))
    c_stem = _mkdiv(16 * wm)
    x = b.conv2d(0, c_stem, 3, stride=2, name="stem.conv")
    x = b.batchnorm(x, name="stem.bn")
    x = b.act(x, "hardswish", name="stem.hs")

    cin, cin_spec = c_stem, 16
    for bi, (k, exp, out, use_se, act, s) in enumerate(_MBV3S_SPEC):
        hidden, cout = _mkdiv(exp * wm), _mkdiv(out * wm)
        pfx = f"bneck{bi}"
        identity = x
        y = x
        # Structural decisions use the *spec* channels so both scales emit
        # the same op sequence regardless of width-multiplier rounding.
        if exp != cin_spec:
            y = b.conv2d(y, hidden, 1, padding=0, name=f"{pfx}.expand")
            y = b.batchnorm(y, name=f"{pfx}.expand.bn")
            y = b.act(y, act, name=f"{pfx}.expand.{act}")
        y = b.dwconv(y, k, stride=s, name=f"{pfx}.dw")
        y = b.batchnorm(y, name=f"{pfx}.dw.bn")
        y = b.act(y, act, name=f"{pfx}.dw.{act}")
        if use_se:
            se_c = _mkdiv(hidden / 4)
            sq = b.globalavgpool(y, keepdims=True, name=f"{pfx}.se.gap")
            sq = b.linear(sq, se_c, name=f"{pfx}.se.fc1")
            sq = b.act(sq, "relu", name=f"{pfx}.se.relu")
            sq = b.linear(sq, hidden, name=f"{pfx}.se.fc2")
            sq = b.act(sq, "hardsigmoid", name=f"{pfx}.se.hsig")
            y = b.mul(y, sq, name=f"{pfx}.se.scale")
        y = b.conv2d(y, cout, 1, padding=0, name=f"{pfx}.project")
        y = b.batchnorm(y, name=f"{pfx}.project.bn")
        if s == 1 and out == cin_spec:
            y = b.add(y, identity, name=f"{pfx}.add")
        x, cin, cin_spec = y, cout, out

    head_c = _mkdiv(576 * wm)
    x = b.conv2d(x, head_c, 1, padding=0, name="head.conv")
    x = b.batchnorm(x, name="head.bn")
    x = b.act(x, "hardswish", name="head.hs")
    x = b.globalavgpool(x, name="head.gap")
    x = b.linear(x, _mkdiv(1024 * wm), name="head.fc1")
    x = b.act(x, "hardswish", name="head.fc1.hs")
    x = b.linear(x, 1000 if scale == "paper" else 10, name="head.fc2")
    return b.g


# ---------------------------------------------------------------------------
# ViT-B/16
# ---------------------------------------------------------------------------

def build_vit_b16(scale: str) -> Graph:
    if scale == "paper":
        img, patch, dim, heads, depth, mlp = 224, 16, 768, 12, 12, 4
    else:
        img, patch, dim, heads, depth, mlp = 32, 8, 96, 3, 12, 4
    b = GraphBuilder("vit_b16", scale, (1, img, img, 3))
    t = (img // patch) ** 2
    x = b.conv2d(0, dim, patch, stride=patch, padding=0, name="patch.conv")
    x = b.reshape(x, (1, t, dim), name="patch.tokens")

    for li in range(depth):
        pfx = f"block{li}"
        identity = x
        y = b.layernorm(x, name=f"{pfx}.ln1")
        y = b.linear(y, 3 * dim, name=f"{pfx}.qkv")
        y = b.attention(y, heads, name=f"{pfx}.attn")
        y = b.linear(y, dim, name=f"{pfx}.proj")
        x = b.add(y, identity, name=f"{pfx}.add1")
        identity = x
        y = b.layernorm(x, name=f"{pfx}.ln2")
        y = b.linear(y, mlp * dim, name=f"{pfx}.fc1")
        y = b.act(y, "gelu", name=f"{pfx}.gelu")
        y = b.linear(y, dim, name=f"{pfx}.fc2")
        x = b.add(y, identity, name=f"{pfx}.add2")

    x = b.layernorm(x, name="head.ln")
    side = img // patch
    x = b.reshape(x, (1, side, side, dim), name="head.grid")
    x = b.globalavgpool(x, name="head.gap")
    x = b.linear(x, 1000 if scale == "paper" else 10, name="head.fc")
    return b.g


# ---------------------------------------------------------------------------
# Swin-T
# ---------------------------------------------------------------------------

def build_swin_t(scale: str) -> Graph:
    if scale == "paper":
        img, patch, dims, depths, heads, win_base, mlp = (
            224, 4, (96, 192, 384, 768), (2, 2, 6, 2), (3, 6, 12, 24), 7, 4)
    else:
        img, patch, dims, depths, heads, win_base, mlp = (
            64, 4, (24, 48, 96, 192), (2, 2, 6, 2), (3, 3, 3, 3), 4, 4)
    b = GraphBuilder("swin_t", scale, (1, img, img, 3))
    x = b.conv2d(0, dims[0], patch, stride=patch, padding=0,
                 name="patch.conv")
    res = img // patch

    for si, (dim, depth, nh) in enumerate(zip(dims, depths, heads)):
        if si > 0:
            # Patch merging: space-to-depth + LN + reduction linear.
            x = b.space_to_depth(x, name=f"stage{si}.merge.s2d")
            x = b.layernorm(x, name=f"stage{si}.merge.ln")
            x = b.linear(x, dim, name=f"stage{si}.merge.reduce")
            res //= 2
        win = min(win_base, res)
        for bi in range(depth):
            # Odd blocks always carry the cyclic-shift pair; the shift
            # amount is 0 when the window covers the whole resolution so
            # both scales emit the same op sequence.
            shifted = bi % 2 == 1
            sh = win // 2 if win < res else 0
            pfx = f"stage{si}.block{bi}"
            identity = x
            y = b.layernorm(x, name=f"{pfx}.ln1")
            if shifted:
                y = b.roll(y, -sh, -sh, name=f"{pfx}.shift")
            y = b.window_part(y, win, name=f"{pfx}.wpart")
            y = b.linear(y, 3 * dim, name=f"{pfx}.qkv")
            y = b.attention(y, nh, name=f"{pfx}.attn")
            y = b.linear(y, dim, name=f"{pfx}.proj")
            y = b.window_rev(y, win, res, res, name=f"{pfx}.wrev")
            if shifted:
                y = b.roll(y, sh, sh, name=f"{pfx}.unshift")
            x = b.add(y, identity, name=f"{pfx}.add1")
            identity = x
            y = b.layernorm(x, name=f"{pfx}.ln2")
            y = b.linear(y, mlp * dim, name=f"{pfx}.fc1")
            y = b.act(y, "gelu", name=f"{pfx}.gelu")
            y = b.linear(y, dim, name=f"{pfx}.fc2")
            x = b.add(y, identity, name=f"{pfx}.add2")

    x = b.layernorm(x, name="head.ln")
    x = b.globalavgpool(x, name="head.gap")
    x = b.linear(x, 1000 if scale == "paper" else 10, name="head.fc")
    return b.g


MODELS = {
    "resnet18": build_resnet18,
    "mobilenet_v2": build_mobilenet_v2,
    "mobilenet_v3_small": build_mobilenet_v3_small,
    "vit_b16": build_vit_b16,
    "swin_t": build_swin_t,
}


def build(model: str, scale: str) -> Graph:
    return MODELS[model](scale)
