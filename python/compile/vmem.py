"""VMEM-footprint and MXU-utilization estimator for the L1 Pallas kernels.

``interpret=True`` gives CPU-numpy timings, which are not a TPU proxy — so
the L1 performance pass optimizes *structure*: per-kernel VMEM residency
(must fit the ~16 MiB/core budget with double-buffering headroom) and the
fraction of MXU-shaped work per grid step.  EXPERIMENTS.md §Perf records
the numbers this module produces.
"""
from __future__ import annotations

import dataclasses

VMEM_BUDGET_BYTES = 16 * 1024 * 1024
# MXU tiles are 128x128; granularity below that wastes systolic cycles.
MXU_DIM = 128


@dataclasses.dataclass
class KernelEstimate:
    name: str
    vmem_bytes: int
    #: fraction of the kernel's FLOPs that map onto full MXU tiles
    mxu_utilization: float
    #: grid steps (HBM->VMEM pipeline length)
    grid_steps: int

    @property
    def fits_vmem(self) -> bool:
        # double buffering: two tiles of each operand in flight
        return 2 * self.vmem_bytes <= VMEM_BUDGET_BYTES


def _tile_util(dim: int, tile: int = MXU_DIM) -> float:
    """Fraction of a systolic dimension actually used by the last tile."""
    if dim >= tile:
        full = dim // tile
        rem = dim % tile
        return (full * tile + rem) / ((full + (1 if rem else 0)) * tile)
    return dim / tile


def matmul_estimate(m: int, k: int, n: int, bm: int = 128, bn: int = 128,
                    bk: int = 128, dtype_bytes: int = 4) -> KernelEstimate:
    """Blocked (sparse) matmul: x-tile + y-tile + out-tile resident."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    vmem = dtype_bytes * (bm * bk + bk * bn + bm * bn)
    grid = -(-m // bm) * -(-n // bn) * -(-k // bk)
    util = _tile_util(bm) * _tile_util(bn) * _tile_util(bk)
    return KernelEstimate("matmul", vmem, util, grid)


def conv_estimate(n: int, h: int, w: int, cin: int, cout: int, kh: int,
                  kw: int, stride: int = 1, padding: int = 0,
                  dtype_bytes: int = 4) -> KernelEstimate:
    """Conv = im2col + matmul with M=N*Ho*Wo, K=Kh*Kw*Cin, N=Cout."""
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    e = matmul_estimate(n * ho * wo, kh * kw * cin, cout,
                        dtype_bytes=dtype_bytes)
    return KernelEstimate("conv2d(im2col)", e.vmem_bytes,
                          e.mxu_utilization, e.grid_steps)


def dwconv_estimate(h: int, w: int, c: int, kh: int, kw: int,
                    cb: int = 32, padding: int = 1,
                    dtype_bytes: int = 4) -> KernelEstimate:
    """Depthwise: (Hp, Wp, cb) slab + weights + output slab; VPU work (no
    MXU), so mxu_utilization reports VPU lane occupancy of the channel
    block (8x128 lanes)."""
    cb = min(cb, c)
    hp, wp = h + 2 * padding, w + 2 * padding
    vmem = dtype_bytes * (hp * wp * cb + kh * kw * cb + h * w * cb)
    lane_util = _tile_util(cb, 128)
    grid = -(-c // cb)
    return KernelEstimate("dwconv", vmem, lane_util, grid)


def attention_estimate(t: int, d: int, dtype_bytes: int = 4
                       ) -> KernelEstimate:
    """Fused SDPA, whole (T,d) per head resident: q,k,v,logits,out."""
    vmem = dtype_bytes * (3 * t * d + t * t + t * d)
    util = _tile_util(t) * _tile_util(d)
    return KernelEstimate("attention", vmem, util, 1)
