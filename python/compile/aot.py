"""AOT export driver: lowers every exec-scale operator of the five models to
HLO text, writes weights + topology JSONs, trains and exports the threshold
predictor, and emits the manifest the rust coordinator loads.

Run once via ``make artifacts``.  Python never runs on the request path.

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, device_model, interp, model, predictor
from .graph_ir import KIND_CLASS, Graph, op_callable, signature, zip_scales

ROOT = pathlib.Path(__file__).resolve().parents[2]
ART = ROOT / "artifacts"

# Ops that are pure data movement at exec scale: the rust engine applies
# them natively (reshape of the host buffer) instead of a PJRT call.
NATIVE_KINDS = {"input", "reshape"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the default HLO printer elides big literals
    # as `constant({...})`, which would silently drop baked-in weights
    # (e.g. the trained threshold predictor) from the interchange text.
    return comp.as_hlo_text(print_large_constants=True)


def export_op_hlo(op, out_path: pathlib.Path) -> None:
    """Lower one exec-scale op (inputs + params as parameters) to HLO."""
    fn = op_callable(op)
    n_in = len(op.in_shapes)

    def wrapped(*args):
        ins = list(args[:n_in])
        ps = list(args[n_in:])
        return (fn(ins, ps),)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in op.in_shapes]
    specs += [jax.ShapeDtypeStruct(s, jnp.float32) for s in op.param_shapes]
    lowered = jax.jit(wrapped).lower(*specs)
    out_path.write_text(to_hlo_text(lowered))


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def export_model(name: str, ops_dir: pathlib.Path, exported: dict,
                 log=print) -> dict:
    """Build, profile, and export one model.  Returns its topology dict."""
    ge = model.build(name, "exec")
    gp = model.build(name, "paper")
    zip_scales(ge, gp)
    params = datagen.init_params(ge, seed=hash(name) % 2 ** 16)
    log(f"[{name}] measuring activation sparsity (exec scale)...")
    sp_out = interp.measure_sparsity(ge, params, n_inputs=2)

    buf, slices = datagen.flatten_params(params)
    mdir = ART / "models" / name
    mdir.mkdir(parents=True, exist_ok=True)
    buf.tofile(mdir / "weights.bin")

    ops_json = []
    for oe, op_ in zip(ge.ops, gp.ops):
        # input sparsity = numel-weighted mean of producers' output sparsity
        if oe.inputs:
            tot = sum(_numel(ge.ops[i].out_shape) for i in oe.inputs)
            sp_in = sum(sp_out[i] * _numel(ge.ops[i].out_shape)
                        for i in oe.inputs) / max(tot, 1)
        else:
            sp_in = 0.0
        rec = {
            "id": oe.id, "name": oe.name, "kind": oe.kind,
            "class": KIND_CLASS[oe.kind], "inputs": oe.inputs,
            "attrs": oe.attrs,
            "exec_in_shapes": [list(s) for s in oe.in_shapes],
            "exec_out_shape": list(oe.out_shape),
            "paper_in_shapes": [list(s) for s in op_.in_shapes],
            "paper_out_shape": list(op_.out_shape),
            "flops_exec": oe.flops, "flops_paper": op_.flops,
            "bytes_in_paper": 4.0 * sum(_numel(s) for s in op_.in_shapes),
            "bytes_out_paper": 4.0 * _numel(op_.out_shape),
            "params_bytes_paper": 4.0 * sum(_numel(s)
                                            for s in op_.param_shapes),
            "sparsity_in": float(sp_in), "sparsity_out": float(sp_out[oe.id]),
            "weights": slices[oe.id],
            "artifact": None,
        }
        if oe.kind not in NATIVE_KINDS:
            sig = signature(oe)
            rel = f"ops/{sig}.hlo.txt"
            if sig not in exported:
                export_op_hlo(oe, ops_dir / f"{sig}.hlo.txt")
                exported[sig] = rel
            rec["artifact"] = rel
        ops_json.append(rec)

    topo = {
        "model": name,
        "input_shape_exec": list(ge.input_shape),
        "input_shape_paper": list(gp.input_shape),
        "total_flops_paper": sum(o.flops for o in gp.ops),
        "total_flops_exec": sum(o.flops for o in ge.ops),
        "weights_file": "weights.bin",
        "ops": ops_json,
    }
    (mdir / "topology.json").write_text(json.dumps(topo))
    log(f"[{name}] ops={len(ops_json)} artifacts(new total)={len(exported)}")
    return topo


def export_predictor(topos: list[dict], log=print) -> None:
    """Train the Transformer-LSTM + baselines, export HLO + dataset."""
    pdir = ART / "predictor"
    pdir.mkdir(parents=True, exist_ok=True)

    graphs = []
    for t in topos:
        gp = model.build(t["model"], "paper")
        sp_in = np.array([o["sparsity_in"] for o in t["ops"]])
        graphs.append((gp, sp_in))
    feats, labels, classes = predictor.build_dataset(graphs)
    log(f"[predictor] dataset: {feats.shape[0]} samples")
    X, Y, M = predictor.to_sequences(feats, labels)
    n = X.shape[0]
    rng = np.random.default_rng(3)
    order = rng.permutation(n)
    n_tr = int(0.8 * n)
    tr, te = order[:n_tr], order[n_tr:]

    t0 = time.time()
    p = predictor.train(X[tr], Y[tr], M[tr], epochs=100, log=log)
    log(f"[predictor] trained in {time.time() - t0:.0f}s "
        f"({predictor.param_count(p)} params)")
    pred = np.asarray(predictor.forward(p, X[te]))
    acc_s, acc_c = predictor.accuracy(pred, Y[te], M[te])
    log(f"[predictor] ours: sparsity acc={acc_s:.3f} intensity acc={acc_c:.3f}")

    w_lr = predictor.fit_linear(X[tr], Y[tr], M[tr])
    pred_lr = predictor.linear_predict(w_lr, X[te])
    acc_s_lr, acc_c_lr = predictor.accuracy(pred_lr, Y[te], M[te])
    log(f"[predictor] LR:   sparsity acc={acc_s_lr:.3f} intensity acc={acc_c_lr:.3f}")

    p_cnn = predictor.train_cnn(X[tr], Y[tr], M[tr], log=log)
    pred_cnn = np.asarray(predictor.cnn_forward(p_cnn, X[te]))
    acc_s_cnn, acc_c_cnn = predictor.accuracy(pred_cnn, Y[te], M[te])
    log(f"[predictor] CNN:  sparsity acc={acc_s_cnn:.3f} intensity acc={acc_c_cnn:.3f}")

    # AOT-export forward passes (batch 1 x SEQ_LEN x 6).
    spec = jax.ShapeDtypeStruct((1, predictor.SEQ_LEN, predictor.N_FEATURES),
                                jnp.float32)
    lowered = jax.jit(lambda x: (predictor.forward(p, x),)).lower(spec)
    (pdir / "thresh_predictor.hlo.txt").write_text(to_hlo_text(lowered))
    lowered = jax.jit(lambda x: (predictor.cnn_forward(p_cnn, x),)).lower(spec)
    (pdir / "cnn_predictor.hlo.txt").write_text(to_hlo_text(lowered))

    (pdir / "dataset.json").write_text(json.dumps({
        "seq_len": predictor.SEQ_LEN,
        "n_features": predictor.N_FEATURES,
        "test_x": X[te].reshape(len(te), -1).tolist(),
        "test_y": Y[te].reshape(len(te), -1).tolist(),
        "test_mask": M[te].tolist(),
        "lr_weights": w_lr.T.tolist(),      # (2, 7) rows: [s; c]
        "accuracy": {
            "ours": [acc_s, acc_c],
            "lr": [acc_s_lr, acc_c_lr],
            "cnn": [acc_s_cnn, acc_c_cnn],
        },
        "model_bytes": {
            "ours": predictor.param_count(p) * 4,
            "lr": int(w_lr.size) * 4,
            "cnn": predictor.param_count(p_cnn) * 4,
        },
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=list(model.MODELS))
    ap.add_argument("--skip-predictor", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    ART.mkdir(exist_ok=True)
    ops_dir = ART / "ops"
    ops_dir.mkdir(exist_ok=True)
    shutil.copy(ROOT / "config" / "devices.json", ART / "devices.json")

    exported: dict = {}
    topos = []
    for name in args.models:
        topos.append(export_model(name, ops_dir, exported))

    if not args.skip_predictor:
        export_predictor(topos)

    (ART / "manifest.json").write_text(json.dumps({
        "models": args.models,
        "n_op_artifacts": len(exported),
        "generated_unix": int(t0),
    }))
    print(f"artifacts done in {time.time() - t0:.0f}s "
          f"({len(exported)} unique op HLOs)")


if __name__ == "__main__":
    main()
