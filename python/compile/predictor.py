"""The SparOA threshold predictor (paper §3): Transformer encoder + BiLSTM
+ sigmoid head, trained to regress per-operator (sparsity, intensity)
scheduling thresholds; plus the LR and CNN baseline predictors of Table 3.

Everything here is build-time Python.  The trained forward pass is
AOT-lowered to HLO (artifacts/predictor/*.hlo.txt) and queried from rust via
PJRT during the offline scheduling phase; it is never on the request path.

Ground truth (paper §3.3): for every operator in the five-model zoo, the
device-model mirror sweeps sparsity / intensity and bisects the boundary
where the optimal processor flips.  Labels carry Gaussian measurement noise
(hardware jitter) calibrated so a perfect regressor lands near the paper's
92.3% / 90.6% ±10% accuracy ceiling.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import device_model as dm
from .graph_ir import KIND_CLASS, Graph

SEQ_LEN = 32
N_FEATURES = 6
D_MODEL = 128
N_HEADS = 4
N_LAYERS = 2
D_FF = 256
D_LSTM = 64            # per direction; concat -> 128
LABEL_NOISE = 0.055


# ---------------------------------------------------------------------------
# Dataset
# ---------------------------------------------------------------------------

def op_features(op, sparsity_in: float) -> list[float]:
    """X = [rho, I, B, C_in, H, W] (paper §3.1), normalized to ~[0,1]."""
    s = op.in_shapes[0] if op.in_shapes else op.out_shape
    if len(s) == 4:
        b, h, w, c = s
    elif len(s) == 3:
        b, h, c = s
        w = 1.0
    else:
        b, c = s[0], s[-1]
        h = w = 1.0
    return [float(sparsity_in),
            dm.norm_intensity(op.flops),
            math.log2(max(b, 1)) / 8.0,
            min(c / 1024.0, 2.0),
            min(h / 256.0, 2.0),
            min(w / 256.0, 2.0)]


def _op_bytes(op) -> tuple[float, float]:
    n_in = sum(int(np.prod(s)) for s in op.in_shapes) if op.in_shapes else 0
    n_out = int(np.prod(op.out_shape))
    n_par = sum(int(np.prod(s)) for s in op.param_shapes)
    return 4.0 * (n_in + n_out + n_par), 4.0 * n_in


def build_dataset(graphs: list[tuple[Graph, np.ndarray]], seed: int = 0):
    """graphs: [(paper_graph, sparsity_in[])].  Returns dict of arrays.

    Each op contributes one sample per device profile, augmented with
    jittered copies (scaled shapes) to reach the paper's ~2000 samples.
    """
    cfg = dm.load()
    rng = np.random.default_rng(seed)
    feats, labels, classes = [], [], []
    for g, sp_in in graphs:
        for dev_name, dev in cfg["devices"].items():
            for op in g.ops:
                if op.kind in ("input", "reshape", "roll", "concat",
                               "window_part", "window_rev",
                               "space_to_depth"):
                    continue
                for aug in range(2):
                    scale = 1.0 if aug == 0 else float(rng.uniform(0.25, 4.0))
                    flops = op.flops * scale
                    bytes_moved, xfer = _op_bytes(op)
                    bytes_moved *= scale
                    xfer *= scale
                    rho = float(np.clip(
                        sp_in[op.id] + (rng.uniform(-0.15, 0.15)
                                        if aug else 0.0), 0.0, 1.0))
                    cls = KIND_CLASS[op.kind]
                    s_star = dm.sparsity_threshold(dev, cls, flops,
                                                   bytes_moved, xfer)
                    c_star = dm.intensity_threshold(dev, cls, flops,
                                                    bytes_moved, rho, xfer)
                    f = op_features(op, rho)
                    f[1] = dm.norm_intensity(flops)
                    feats.append(f)
                    labels.append([s_star, c_star])
                    classes.append(cls)
    feats = np.asarray(feats, np.float32)
    labels = np.asarray(labels, np.float32)
    # hardware measurement jitter on the ground-truth labels
    labels = np.clip(labels + rng.normal(0.0, LABEL_NOISE, labels.shape)
                     .astype(np.float32), 0.0, 1.0)
    return feats, labels, classes


def to_sequences(feats: np.ndarray, labels: np.ndarray,
                 seq_len: int = SEQ_LEN):
    """Chop the (shuffled-by-construction) op stream into fixed windows.
    Returns (X [n,T,6], Y [n,T,2], mask [n,T])."""
    n = feats.shape[0]
    n_seq = math.ceil(n / seq_len)
    X = np.zeros((n_seq, seq_len, feats.shape[1]), np.float32)
    Y = np.zeros((n_seq, seq_len, labels.shape[1]), np.float32)
    M = np.zeros((n_seq, seq_len), np.float32)
    for i in range(n_seq):
        chunk = slice(i * seq_len, min((i + 1) * seq_len, n))
        k = chunk.stop - chunk.start
        X[i, :k] = feats[chunk]
        Y[i, :k] = labels[chunk]
        M[i, :k] = 1.0
    return X, Y, M


# ---------------------------------------------------------------------------
# Transformer-LSTM model (pure jax, explicit params pytree)
# ---------------------------------------------------------------------------

def init_params(key) -> dict:
    ks = jax.random.split(key, 32)
    ki = iter(ks)

    def dense(k, din, dout):
        return {"w": jax.random.normal(k, (din, dout)) * (1.0 / din) ** 0.5,
                "b": jnp.zeros(dout)}

    p = {"embed": dense(next(ki), N_FEATURES, D_MODEL), "layers": []}
    for _ in range(N_LAYERS):
        p["layers"].append({
            "qkv": dense(next(ki), D_MODEL, 3 * D_MODEL),
            "proj": dense(next(ki), D_MODEL, D_MODEL),
            "ln1_g": jnp.ones(D_MODEL), "ln1_b": jnp.zeros(D_MODEL),
            "ff1": dense(next(ki), D_MODEL, D_FF),
            "ff2": dense(next(ki), D_FF, D_MODEL),
            "ln2_g": jnp.ones(D_MODEL), "ln2_b": jnp.zeros(D_MODEL),
        })
    for d in ("fwd", "bwd"):
        p[f"lstm_{d}"] = {
            "wx": jax.random.normal(next(ki), (D_MODEL, 4 * D_LSTM))
            * (1.0 / D_MODEL) ** 0.5,
            "wh": jax.random.normal(next(ki), (D_LSTM, 4 * D_LSTM))
            * (1.0 / D_LSTM) ** 0.5,
            "b": jnp.zeros(4 * D_LSTM),
        }
    p["head"] = dense(next(ki), 2 * D_LSTM, 2)
    return p


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _mhsa(x, p):
    b, t, d = x.shape
    hd = d // N_HEADS
    qkv = x @ p["qkv"]["w"] + p["qkv"]["b"]
    qkv = qkv.reshape(b, t, 3, N_HEADS, hd).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]            # (b, H, t, hd)
    logits = q @ k.transpose(0, 1, 3, 2) / hd ** 0.5
    a = jax.nn.softmax(logits, -1) @ v          # (b, H, t, hd)
    a = a.transpose(0, 2, 1, 3).reshape(b, t, d)
    return a @ p["proj"]["w"] + p["proj"]["b"]


def _lstm_scan(x, p, reverse=False):
    """x: (b, t, D_MODEL) -> (b, t, D_LSTM)."""
    b, t, _ = x.shape
    xs = jnp.flip(x, 1) if reverse else x

    def step(carry, xt):
        h, c = carry
        z = xt @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, -1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((b, D_LSTM))
    c0 = jnp.zeros((b, D_LSTM))
    _, hs = jax.lax.scan(step, (h0, c0), xs.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)
    return jnp.flip(hs, 1) if reverse else hs


def forward(p: dict, x: jax.Array) -> jax.Array:
    """x: (b, T, 6) -> (b, T, 2) thresholds in (0, 1)."""
    h = x @ p["embed"]["w"] + p["embed"]["b"]
    for lp in p["layers"]:
        h = _ln(h + _mhsa(h, lp), lp["ln1_g"], lp["ln1_b"])    # Eq. (3)
        ff = jax.nn.relu(h @ lp["ff1"]["w"] + lp["ff1"]["b"])
        ff = ff @ lp["ff2"]["w"] + lp["ff2"]["b"]
        h = _ln(h + ff, lp["ln2_g"], lp["ln2_b"])
    hf = _lstm_scan(h, p["lstm_fwd"])                          # Eq. (4)
    hb = _lstm_scan(h, p["lstm_bwd"], reverse=True)
    h = jnp.concatenate([hf, hb], -1)
    out = h @ p["head"]["w"] + p["head"]["b"]                  # Eq. (5)
    return jax.nn.sigmoid(out)


def loss_fn(p, x, y, m):
    pred = forward(p, x)
    err = jnp.sum((pred - y) ** 2, -1) * m                     # Eq. (6)
    return jnp.sum(err) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# Adam (manual, no optax dependency)
# ---------------------------------------------------------------------------

def adam_init(p):
    z = jax.tree.map(jnp.zeros_like, p)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, p), "t": 0}


def adam_step(p, grads, st, lr=1e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = st["t"] + 1
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, st["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, st["v"], grads)
    mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
    p = jax.tree.map(lambda w, a, b: w - lr * a / (jnp.sqrt(b) + eps),
                     p, mh, vh)
    return p, {"m": m, "v": v, "t": t}


def train(X, Y, M, epochs=100, lr=3e-4, batch=16, seed=0, log=print):
    key = jax.random.PRNGKey(seed)
    p = init_params(key)
    st = adam_init(p)

    @jax.jit
    def step(p, st, x, y, m):
        l, g = jax.value_and_grad(loss_fn)(p, x, y, m)
        p, st = adam_step(p, g, st, lr=lr)
        return p, st, l

    n = X.shape[0]
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        order = rng.permutation(n)
        tot = 0.0
        for i in range(0, n, batch):
            idx = order[i:i + batch]
            p, st, l = step(p, st, X[idx], Y[idx], M[idx])
            tot += float(l) * len(idx)
        if ep % 10 == 0 or ep == epochs - 1:
            log(f"  predictor epoch {ep:3d} loss={tot / n:.5f}")
    return p


def accuracy(pred: np.ndarray, y: np.ndarray, m: np.ndarray,
             tol: float = 0.1):
    """±10%-of-range accuracy per output (sparsity, intensity)."""
    ok = np.abs(pred - y) < tol
    msum = max(m.sum(), 1.0)
    return (float((ok[..., 0] * m).sum() / msum),
            float((ok[..., 1] * m).sum() / msum))


# ---------------------------------------------------------------------------
# Baseline predictors (Table 3)
# ---------------------------------------------------------------------------

def fit_linear(X, Y, M):
    """Ridge regression on flattened (feature -> threshold) pairs."""
    f = X.reshape(-1, X.shape[-1])[M.reshape(-1) > 0]
    y = Y.reshape(-1, Y.shape[-1])[M.reshape(-1) > 0]
    f1 = np.concatenate([f, np.ones((f.shape[0], 1), np.float32)], 1)
    w = np.linalg.solve(f1.T @ f1 + 1e-3 * np.eye(f1.shape[1]),
                        f1.T @ y)
    return w.astype(np.float32)          # (7, 2)


def linear_predict(w, X):
    f1 = np.concatenate([X, np.ones(X.shape[:-1] + (1,), np.float32)], -1)
    return f1 @ w


def init_cnn(key):
    """Small 1-D CNN over the op sequence (kernel 3): local context only."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "c1": jax.random.normal(k1, (3, N_FEATURES, 32)) * 0.2,
        "b1": jnp.zeros(32),
        "c2": jax.random.normal(k2, (3, 32, 32)) * 0.1,
        "b2": jnp.zeros(32),
        "c3": jax.random.normal(k3, (1, 32, 2)) * 0.1,
        "b3": jnp.zeros(2),
    }


def cnn_forward(p, x):
    def conv1d(h, w, b):
        return jax.lax.conv_general_dilated(
            h, w, (1,), "SAME", dimension_numbers=("NTC", "TIO", "NTC")) + b
    h = jax.nn.relu(conv1d(x, p["c1"], p["b1"]))
    h = jax.nn.relu(conv1d(h, p["c2"], p["b2"]))
    return jax.nn.sigmoid(conv1d(h, p["c3"], p["b3"]))


def train_cnn(X, Y, M, epochs=60, lr=3e-3, seed=1, log=print):
    p = init_cnn(jax.random.PRNGKey(seed))
    st = adam_init(p)

    def loss(p, x, y, m):
        pred = cnn_forward(p, x)
        return jnp.sum(jnp.sum((pred - y) ** 2, -1) * m) / jnp.maximum(
            jnp.sum(m), 1.0)

    @jax.jit
    def step(p, st, x, y, m):
        l, g = jax.value_and_grad(loss)(p, x, y, m)
        p, st = adam_step(p, g, st, lr=lr)
        return p, st, l

    for ep in range(epochs):
        p, st, l = step(p, st, X, Y, M)
        if ep % 20 == 0 or ep == epochs - 1:
            log(f"  cnn epoch {ep:3d} loss={float(l):.5f}")
    return p


def param_count(p) -> int:
    return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(p))
